"""The closed estimation loop: full-parameter adjoints (mus / sigmas / drift
rho) through the fused kernels and the custom VJP, the posterior-sensitivity
chain through the NIG parameters, online BIC family selection with
hysteresis, the adaptive refresh cadence, and the balancer's full
estimation-state round-trip.

Acceptance anchors (ISSUE 4):
  * ``ops.frontier_moments`` returns nonzero cotangents for mus, sigmas and
    drift ``extra`` on every impl, matching central differences to <= 1e-3
    relative on the dominant coordinates and autodiff-through-the-quadrature
    to <= 1e-4 in norm — w=0 / sigma=0 edge channels included;
  * ``family="auto"`` recovers the generating family on simulated normal,
    lognormal and drift traces for >= 2/3 of post-burn-in ticks;
  * ``state_dict``/``from_state_dict`` round-trips the FULL estimation state
    (posteriors, selected family + extras, hysteresis counters, history,
    cached solve, refresh phase): a restored balancer resumes identical
    ticks.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Drift, Empirical, estimation_fragility,
                        moment_sensitivity, nig_init, nig_update_batch,
                        posterior_sensitivity, resolve_family)
from repro.core.bayes import nig_estimate_ses, nig_point_estimates
from repro.core.partitioner import optimize_weights
from repro.kernels import ops, ref
from repro.kernels.frontier_grid import frontier_grid_with_grads
from repro.sched.balancer import UncertaintyAwareBalancer
from repro.sim import ClusterSim


def _problem(k, seed=0, cov=(0.05, 0.3)):
    rng = np.random.default_rng(seed)
    mus = rng.uniform(10, 40, k).astype(np.float32)
    sigmas = (mus * rng.uniform(*cov, k)).astype(np.float32)
    return jnp.asarray(mus), jnp.asarray(sigmas)


def _candidates(F, k, seed=0):
    rng = np.random.default_rng(seed)
    e = rng.exponential(size=(F, k))
    return jnp.asarray(e / e.sum(axis=1, keepdims=True), jnp.float32)


def _families(k, seed):
    rng = np.random.default_rng(seed)
    mus, sigmas = _problem(k, seed=seed)
    emp = Empirical.from_samples(
        rng.normal(np.asarray(mus)[None, :], np.asarray(sigmas)[None, :],
                   size=(3000, k)))
    return [("normal", "normal"),
            ("lognormal", "lognormal"),
            ("drift", Drift(rng.uniform(0.1, 0.7, k).astype(np.float32))),
            ("empirical", emp)]


def _rel(a, b):
    a, b = np.asarray(a), np.asarray(b)
    nb = np.linalg.norm(b)
    return float(np.linalg.norm(a - b) / (nb if nb > 0 else 1.0))


class TestParamAdjointParity:
    """The tentpole's kernel surface: dmus/dsigmas/dextra on every family."""

    @pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
    @pytest.mark.parametrize("fam_id", ["normal", "lognormal", "drift",
                                        "empirical"])
    def test_custom_vjp_matches_autodiff(self, impl, fam_id):
        """jax.grad of frontier_moments w.r.t. mus and sigmas == autodiff
        through the family quadrature, zero-weight rows included."""
        k, F, num_t = 5, 9, 512
        mus, sigmas = _problem(k, seed=3)
        fam = dict(_families(k, seed=3))[fam_id]
        dist_id, extra = resolve_family(fam, k)
        extra = jnp.asarray(extra, jnp.float32)
        W = _candidates(F, k, seed=F).at[0, 0].set(0.0)

        for arg, axis in (("mus", 0), ("sigmas", 1)):
            def f_ops(x, axis=axis, arg=arg):
                a = (x, sigmas) if arg == "mus" else (mus, x)
                return jnp.sum(ops.frontier_moments(
                    W, *a, num_t=num_t, impl=impl, family=fam)[axis])

            def f_ref(x, axis=axis, arg=arg):
                a = (x, sigmas) if arg == "mus" else (mus, x)
                return jnp.sum(ref.frontier_grid_ref(
                    W, *a, num_t=num_t, dist_id=dist_id, extra=extra)[axis])

            x0 = mus if arg == "mus" else sigmas
            g = jax.grad(f_ops)(x0)
            ga = jax.grad(f_ref)(x0)
            if fam_id == "empirical":
                # the mixture CDF never reads (mu, sigma): exactly zero both
                # ways — the documented "re-fit, don't descend" contract
                assert not np.any(np.asarray(g)) and not np.any(np.asarray(ga))
            else:
                assert np.any(np.asarray(g))
                assert _rel(g, ga) <= 1e-4, (fam_id, impl, arg)

    @pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
    def test_drift_extra_cotangent(self, impl):
        """Drift's rho (extra row 0) gets a real, autodiff-parity cotangent
        through the family tuple path."""
        k = 5
        mus, sigmas = _problem(k, seed=7)
        rho = np.random.default_rng(7).uniform(0.2, 0.8, k).astype(np.float32)
        dist_id, extra = resolve_family(Drift(rho), k)
        extra = jnp.asarray(extra, jnp.float32)
        W = _candidates(6, k, seed=1)
        g = jax.grad(lambda ex: jnp.sum(ops.frontier_moments(
            W, mus, sigmas, num_t=512, impl=impl,
            family=(dist_id, ex))[0]))(extra)
        ga = jax.grad(lambda ex: jnp.sum(ref.frontier_grid_ref(
            W, mus, sigmas, num_t=512, dist_id=dist_id, extra=ex)[0]))(extra)
        assert np.any(np.asarray(g))
        assert _rel(g, ga) <= 1e-4

    @pytest.mark.parametrize("fam_id", ["normal", "lognormal", "drift"])
    def test_finite_differences(self, fam_id):
        """Acceptance: parameter cotangents match central differences to
        <= 1e-3 relative on the dominant coordinates."""
        k, num_t = 5, 2048
        mus, sigmas = _problem(k, seed=9)
        fam = dict(_families(k, seed=9))[fam_id]
        w = jnp.asarray(np.full(k, 1.0 / k, np.float32))[None, :]

        outs = ops.frontier_moments_with_grads(
            w, mus, sigmas, num_t=num_t, family=fam, param_grads=True)
        for name, x0, g_row in (("mus", mus, outs[4]),
                                ("sigmas", sigmas, outs[6])):
            g = np.asarray(g_row)[0]
            x0 = np.asarray(x0)

            def f(x, name=name):
                a = (jnp.asarray(x), sigmas) if name == "mus" \
                    else (mus, jnp.asarray(x))
                return float(ops.frontier_moments(
                    w, *a, num_t=num_t, family=fam)[0][0])

            # difference the dominant coordinates; the step must be large
            # enough that the f32 forward's ~1e-6 absolute noise stays well
            # under the 1e-3 acceptance bar (truncation is negligible here)
            for i in np.argsort(-np.abs(g))[:2]:
                eps = max(5e-3 * abs(x0[i]), 5e-3)
                xp, xm = x0.copy(), x0.copy()
                xp[i] += eps
                xm[i] -= eps
                fd = (f(xp) - f(xm)) / (2 * eps)
                np.testing.assert_allclose(g[i], fd, rtol=1e-3, atol=1e-6,
                                           err_msg=f"{fam_id}:{name}[{i}]")

    def test_drift_rho_finite_differences(self):
        k, num_t = 4, 2048
        mus, sigmas = _problem(k, seed=11)
        rho = np.random.default_rng(11).uniform(0.3, 0.9, k).astype(np.float32)
        w = jnp.asarray(np.full(k, 1.0 / k, np.float32))[None, :]
        dist_id, extra = resolve_family(Drift(rho), k)
        outs = ops.frontier_moments_with_grads(
            w, mus, sigmas, num_t=num_t, family=Drift(rho), param_grads=True)
        g = np.asarray(outs[8])[0]
        assert np.any(g)

        def f(ex):
            return float(ops.frontier_moments(
                w, mus, sigmas, num_t=num_t,
                family=(dist_id, jnp.asarray(ex, jnp.float32)))[0][0])

        ex0 = np.asarray(extra, np.float64)
        for i in np.argsort(-np.abs(g))[:2]:
            eps = 1e-2
            xp, xm = ex0.copy(), ex0.copy()
            xp[0, i] += eps
            xm[0, i] -= eps
            fd = (f(xp) - f(xm)) / (2 * eps)
            np.testing.assert_allclose(g[i], fd, rtol=1e-3, atol=1e-6)

    def test_sigma_zero_edge_channel(self):
        """A sigma=0 (point-mass) channel has zero direct parameter gradient
        but still carries the moving-grid term when it sets tmax — parity
        with autodiff through the where-branches must survive."""
        mus = jnp.asarray([20.0, 35.0, 10.0], jnp.float32)
        sigmas = jnp.asarray([4.0, 0.0, 2.0], jnp.float32)  # ch1 sets tmax
        W = jnp.asarray([[0.3, 0.5, 0.2], [0.2, 0.6, 0.2]], jnp.float32)
        g = jax.grad(lambda m: jnp.sum(ops.frontier_moments(
            W, m, sigmas, num_t=512)[0]))(mus)
        ga = jax.grad(lambda m: jnp.sum(ref.frontier_grid_ref(
            W, m, sigmas, num_t=512)[0]))(mus)
        assert _rel(g, ga) <= 1e-4
        assert np.any(np.asarray(g))

    @pytest.mark.parametrize("fam_id", ["normal", "lognormal", "drift",
                                        "empirical"])
    def test_param_kernel_matches_ref(self, fam_id):
        """The fused Pallas kernel's param_grads outputs == the ref oracle's,
        all ten outputs, on the interpreter backend."""
        k, F, num_t, bf = 5, 8, 256, 4
        mus, sigmas = _problem(k, seed=F)
        fam = dict(_families(k, seed=F))[fam_id]
        dist_id, extra = resolve_family(fam, k)
        extra = jnp.asarray(extra, jnp.float32)
        W = _candidates(F, k, seed=k)
        outs_k = frontier_grid_with_grads(W, mus, sigmas, extra, num_t=num_t,
                                          block_f=bf, interpret=True,
                                          dist_id=dist_id, param_grads=True)
        outs_r = ref.frontier_grid_with_grads_ref(
            W, mus, sigmas, num_t=num_t, dist_id=dist_id, extra=extra,
            param_grads=True)
        names = ("mu", "var", "dW", "dvW", "dM", "dvM", "dS", "dvS",
                 "dE", "dvE")
        assert len(outs_k) == len(outs_r) == 10
        for name, a, b in zip(names, outs_k, outs_r):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4,
                atol=3e-5 * float(np.max(np.abs(np.asarray(b)))) + 1e-12,
                err_msg=f"{fam_id}:{name}")

    def test_one_launch_param_mode(self):
        """param_grads widens the SAME launch: 10 outputs, consistent with
        the 4-output mode on the shared prefix."""
        k = 4
        mus, sigmas = _problem(k, seed=2)
        W = _candidates(6, k, seed=3)
        o4 = ops.frontier_moments_with_grads(W, mus, sigmas, num_t=256,
                                             block_f=4)
        o10 = ops.frontier_moments_with_grads(W, mus, sigmas, num_t=256,
                                              block_f=4, param_grads=True)
        assert len(o4) == 4 and len(o10) == 10
        for a, b in zip(o4, o10):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestPosteriorSensitivity:
    # repro: allow[RPA001] NIG posterior built from raw observations —
    # family-agnostic by construction (conjugate normal-gamma update)
    def _posterior(self, k, mus, sigmas, n_obs=30, seed=0):
        rng = np.random.default_rng(seed)
        nig = nig_init(k)
        for _ in range(n_obs):
            r = rng.normal(np.asarray(mus), np.asarray(sigmas))
            nig = nig_update_batch(nig, jnp.asarray(r, jnp.float32),
                                   jnp.ones(k, jnp.float32))
        return nig

    def test_chain_rule_matches_numeric(self):
        """d(mu)/d(posterior params) via the closed-form chain == numerically
        differencing the whole pipeline (point estimates -> solve)."""
        k = 4
        mus, sigmas = _problem(k, seed=4)
        nig = self._posterior(k, mus, sigmas)
        w = np.full(k, 1.0 / k)
        mu_hat, sig_hat = nig_point_estimates(nig)
        sens = moment_sensitivity(w, mu_hat, sig_hat, num_t=2048)
        ps = posterior_sensitivity(sens, nig)

        def predict(nig_mod):
            m, s = nig_point_estimates(nig_mod)
            return float(ops.frontier_moments(
                jnp.asarray(w, jnp.float32)[None, :], m, s,
                num_t=2048)[0][0])

        for field in ("m", "kappa", "alpha", "beta"):
            grads = np.asarray(getattr(ps, f"dmu_d{field}"))
            i = int(np.argmax(np.abs(grads)))
            base = np.asarray(getattr(nig, field))
            eps = max(2e-2 * abs(base[i]), 1e-3)
            up = base.copy()
            up[i] += eps
            dn = base.copy()
            dn[i] -= eps
            fd = (predict(nig._replace(**{field: jnp.asarray(up)}))
                  - predict(nig._replace(**{field: jnp.asarray(dn)}))) \
                / (2 * eps)
            np.testing.assert_allclose(grads[i], fd, rtol=2e-2, atol=1e-7,
                                       err_msg=field)

    def test_fragility_shrinks_with_data(self):
        """More observations -> tighter posteriors -> smaller delta-method
        fragility (the adaptive-refresh signal)."""
        k = 4
        mus, sigmas = _problem(k, seed=5)
        w = np.full(k, 1.0 / k)
        frs = []
        for n_obs in (5, 40, 200):
            nig = self._posterior(k, mus, sigmas, n_obs=n_obs)
            mu_hat, sig_hat = nig_point_estimates(nig)
            sens = moment_sensitivity(w, mu_hat, sig_hat, num_t=512)
            frs.append(estimation_fragility(sens, nig))
        assert frs[0] > frs[1] > frs[2] > 0

    def test_ses_shrink_with_data(self):
        k = 3
        mus, sigmas = _problem(k, seed=6)
        n_small = self._posterior(k, mus, sigmas, n_obs=5)
        n_big = self._posterior(k, mus, sigmas, n_obs=100)
        se_mu_s, se_sg_s = nig_estimate_ses(n_small)
        se_mu_b, se_sg_b = nig_estimate_ses(n_big)
        assert np.all(np.asarray(se_mu_b) < np.asarray(se_mu_s))
        assert np.all(np.asarray(se_sg_b) < np.asarray(se_sg_s))

    def test_optimize_weights_returns_sensitivity_and_risk_scores(self):
        k = 5
        mus, sigmas = _problem(k, seed=8)
        nig = self._posterior(k, mus, sigmas, n_obs=6)
        dec, report = optimize_weights(mus, sigmas, lam=0.05, steps=40,
                                       num_t=256, restarts=0,
                                       posterior=nig, risk_lam=0.5,
                                       return_sensitivity=True)
        assert dec.method == "pgd-simplex-risk"
        assert report.fragility > 0
        assert report.sens.mu > 0
        assert np.any(report.dmu_dm) and np.any(report.dmu_dbeta)
        # without a posterior: a MomentSensitivity, not the chained report
        dec2, sens2 = optimize_weights(mus, sigmas, lam=0.05, steps=40,
                                       num_t=256, restarts=0,
                                       return_sensitivity=True)
        assert not hasattr(sens2, "fragility")
        assert np.any(sens2.dmu_dmus)


class TestAutoFamily:
    """Acceptance: family="auto" recovers the generating family (>= 2/3 of
    post-burn-in ticks) on simulated traces of each regime."""

    def _run(self, dist, steps=72, n=6, seed=0, **hetero_kw):
        sim = ClusterSim.heterogeneous(n, seed=seed, dist=dist, **hetero_kw)
        bal = UncertaintyAwareBalancer(
            n, lam=0.02, family="auto", refresh_every=4, pgd_steps=30,
            num_t=192, auto_every=8, auto_min_obs=16, hysteresis=2)
        fams = []
        for _ in range(steps):
            w = bal.weights()
            _, durs = sim.run_step(w)
            bal.observe(durs, w)
            fams.append(bal.selected_family.dist_id)
        post = fams[steps // 3:]
        return sum(f == dist for f in post) / len(post), bal

    def test_recovers_normal(self):
        frac, _ = self._run("normal")
        assert frac >= 2 / 3

    def test_recovers_lognormal(self):
        frac, _ = self._run("lognormal", cov_range=(0.3, 0.6))
        assert frac >= 2 / 3

    def test_recovers_drift(self):
        # straggle that actually matters (and tight noise): with a static
        # split, within-work drift is unidentifiable — the balancer's
        # exploration probe is what makes this recoverable at all
        frac, _ = self._run("drift", cov_range=(0.02, 0.08),
                            rho_range=(1.5, 3.0))
        assert frac >= 2 / 3

    def test_switch_invalidates_cache_and_needs_hysteresis(self):
        """A challenger must win `hysteresis` consecutive passes; the switch
        drops the cached solve."""
        n = 4
        rng = np.random.default_rng(0)
        bal = UncertaintyAwareBalancer(n, family="auto", refresh_every=100,
                                       pgd_steps=20, num_t=128, auto_every=4,
                                       auto_min_obs=8, hysteresis=2)
        mus = rng.uniform(10, 20, n)
        s2 = np.log1p(0.5 ** 2)
        base = np.log(mus) - s2 / 2
        switched_at = None
        for i in range(40):
            w = bal.weights()
            r = rng.lognormal(base, np.sqrt(s2))
            bal.observe(r * w, w)   # rates r under weights w
            if switched_at is None and bal.selected_family.dist_id != "normal":
                switched_at = i
        assert bal.selected_family.dist_id == "lognormal"
        # hysteresis: the first scoring pass alone must not have switched
        assert switched_at is not None and switched_at + 1 > bal.auto_every

    def test_selection_is_scale_invariant(self):
        """Review regression: the lognormal fit's variance floor must live in
        log space (scale-free) — the same lognormal-generated data must win
        regardless of the rate units (seconds vs microseconds)."""
        from repro.core.bayes import score_families

        rng = np.random.default_rng(5)
        N, K = 80, 8
        mus = rng.uniform(10, 30, K)
        s2 = np.log1p(0.4 ** 2)
        base = np.log(mus) - s2 / 2
        r = rng.lognormal(base, np.sqrt(s2), size=(N, K))
        works = np.full((N, K), 1.0 / K)
        mask = np.ones((N, K))
        for scale in (1.0, 1e-4, 1e5):
            s = score_families(r * scale, works, mask)
            assert s.winner == "lognormal", (scale, s.bics)

    def test_idle_channels_do_not_nan_the_scores(self):
        """Channels idle for the whole window (work==0 masks every sample)
        must not NaN the empirical BIC or poison the fitted mixture — the
        review-found failure mode of masked EM columns."""
        from repro.core.bayes import score_families

        rng = np.random.default_rng(3)
        N, K = 48, 6
        mus = rng.uniform(10, 30, K)
        rates = rng.normal(mus, mus * 0.1, size=(N, K))
        works = np.full((N, K), 1.0 / K)
        mask = np.ones((N, K))
        mask[:, 2] = 0.0             # fully idle channel
        mask[5:, 4] = 0.0            # sparse channel (below min_obs)
        s = score_families(rates, works, mask, min_obs=8)
        assert all(np.isfinite(v) for v in s.bics.values()), s.bics
        Wg, Mg, Sg = s.gmm
        assert np.isfinite(Wg).all() and np.isfinite(Mg).all() \
            and np.isfinite(Sg).all()
        # starved channels carry the pooled-fleet fallback, not a point mass
        assert Sg[:, 2].max() > 0 and abs(Mg[0, 2]) > 1.0

    def test_probe_respects_min_weight_floor(self):
        """The exploration probe is applied before the min_weight floor, so
        auto mode keeps the floor's documented guarantee — the renormalized
        bound min_weight / (1 + k * min_weight) — instead of dipping a full
        probe amplitude below it."""
        floor, k = 0.24, 4
        bound = floor / (1 + k * floor)
        bal = UncertaintyAwareBalancer(k, family="auto", min_weight=floor,
                                       pgd_steps=15, num_t=128)
        for _ in range(3):
            w = bal.weights()
            assert w.min() >= bound - 1e-9, w
            bal.observe(np.full(k, 1.0) * w, w)

    def test_fixed_family_mode_unchanged(self):
        """family != "auto" keeps the legacy behavior: no history scoring,
        no exploration probe, selected_family == configured family."""
        bal = UncertaintyAwareBalancer(3, family="lognormal")
        assert bal.selected_family.dist_id == "lognormal"
        w1 = bal.weights()
        w2 = bal.weights()
        np.testing.assert_array_equal(w1, w2)   # no per-tick probe


class TestBalancerStateRoundTrip:
    """Satellite bugfix: the FULL estimation state round-trips — a restored
    balancer resumes identical ticks."""

    def test_identical_ticks_after_restore(self):
        import json

        n = 6
        sim_a = ClusterSim.heterogeneous(n, seed=3, dist="lognormal",
                                         cov_range=(0.3, 0.5))
        bal = UncertaintyAwareBalancer(
            n, lam=0.02, family="auto", refresh_every=4, pgd_steps=25,
            num_t=128, auto_every=6, auto_min_obs=10, hysteresis=2,
            adaptive_refresh=True, risk_lam=0.2)
        for _ in range(30):
            w = bal.weights()
            _, durs = sim_a.run_step(w)
            bal.observe(durs, w)

        # serialize THROUGH json: checkpoints store this dict in meta.json
        blob = json.dumps(bal.state_dict())
        b2 = UncertaintyAwareBalancer.from_state_dict(json.loads(blob))
        assert b2.selected_family.dist_id == bal.selected_family.dist_id
        assert b2._challenger == bal._challenger
        assert b2._challenger_count == bal._challenger_count
        assert b2._obs_count == bal._obs_count
        assert b2.effective_refresh == bal.effective_refresh
        # the cache key round-trips VERBATIM (a canonical JSON string): a
        # solve cached under a per-call family override must still read as
        # stale after restore, exactly as in the original process
        assert b2._cached_family_key == bal._cached_family_key

        sim_b1 = ClusterSim.heterogeneous(n, seed=9, dist="lognormal")
        sim_b2 = ClusterSim.heterogeneous(n, seed=9, dist="lognormal")
        for i in range(15):
            w1, w2 = bal.weights(), b2.weights()
            np.testing.assert_allclose(w1, w2, rtol=0, atol=0,
                                       err_msg=f"tick {i}")
            _, d1 = sim_b1.run_step(w1)
            _, d2 = sim_b2.run_step(w2)
            bal.observe(d1, w1)
            b2.observe(d2, w2)
            assert (bal.selected_family.dist_id
                    == b2.selected_family.dist_id), f"tick {i}"

    def test_override_cached_solve_stale_after_restore(self):
        """Review regression: cache a solve under a family OVERRIDE (the
        straggler policy's Drift path), round-trip, and check the restored
        balancer re-solves under the configured family instead of serving
        the override-cached weights."""
        import json

        n = 4
        bal = UncertaintyAwareBalancer(n, lam=0.02, family="normal",
                                       refresh_every=50, pgd_steps=20,
                                       num_t=128)
        rng = np.random.default_rng(1)
        for _ in range(4):
            w = np.full(n, 1.0 / n)
            bal.observe(rng.normal(15, 1, n) * w, w)
        from repro.core import Drift
        w_override = bal.weights(family=Drift(np.asarray(
            [2.0, 0.0, 0.0, 0.0], np.float32)))   # cached under Drift key
        b2 = UncertaintyAwareBalancer.from_state_dict(
            json.loads(json.dumps(bal.state_dict())))
        # both must agree the cache is stale for the configured family
        w1, w2 = bal.weights(), b2.weights()
        np.testing.assert_allclose(w1, w2)
        assert not np.allclose(w1, w_override)

    def test_legacy_state_dict_still_loads(self):
        """Pre-auto checkpoints (nig + family only) restore with defaults."""
        b = UncertaintyAwareBalancer(3, lam=0.1, family="drift")
        legacy = {"num_channels": 3, "lam": 0.1, "policy": "frontier",
                  "family": {"dist_id": "drift", "rho": [0.1, 0.2, 0.3]},
                  "nig": {k: np.asarray(v).tolist()
                          for k, v in b._nig._asdict().items()}}
        b2 = UncertaintyAwareBalancer.from_state_dict(legacy)
        assert b2.selected_family.dist_id == "drift"
        assert b2.num_channels == 3


class TestDeprecatedNormalShim:
    def test_core_normal_warns(self):
        import sys
        import warnings

        sys.modules.pop("repro.core.normal", None)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            import repro.core.normal  # noqa: F401  # repro: allow[RPA050] the deprecation test itself
        assert any(issubclass(w.category, DeprecationWarning) for w in rec)

    def test_core_import_does_not_warn(self):
        """No in-repo module imports the shim: importing repro.core (and the
        modules that used to ride it) is deprecation-clean."""
        import os
        import subprocess
        import sys

        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ)
        env["PYTHONPATH"] = (os.path.join(root, "src")
                             + os.pathsep + env.get("PYTHONPATH", ""))
        code = ("import warnings; warnings.simplefilter('error', "
                "DeprecationWarning); import repro.core, "
                "repro.core.maxstat, repro.core.partitioner, "
                "repro.sched.balancer")
        res = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, env=env,
                             cwd=root)
        assert res.returncode == 0, res.stderr
