"""Integration: trainer loop, checkpoint restart, partitioned step, serving."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_step, restore, save
from repro.configs import get_config
from repro.data import SyntheticStream
from repro.models import build_model
from repro.optim import (adamw_init, adamw_update, cosine_schedule,
                         dequantize_int8, quantize_int8)
from repro.train import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


class TestCheckpoint:
    def test_roundtrip_exact(self):
        cfg = get_config("smollm-360m").tiny()
        model = build_model(cfg)
        params = model.init(KEY)
        with tempfile.TemporaryDirectory() as d:
            save(d, 7, params, {"note": "x"})
            assert latest_step(d) == 7
            restored, meta = restore(d, params)
            assert meta["step"] == 7 and meta["note"] == "x"
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_latest_and_gc(self):
        with tempfile.TemporaryDirectory() as d:
            tree = {"w": jnp.ones((4,))}
            for s in (1, 2, 3):
                save(d, s, tree)
            assert latest_step(d) == 3

    def test_trainer_resume_continues_at_step(self):
        cfg = get_config("smollm-360m").tiny().replace(remat=False)
        model = build_model(cfg)
        with tempfile.TemporaryDirectory() as d:
            t1 = TrainerConfig(steps=4, batch=2, seq=16, ckpt_dir=d,
                               ckpt_interval=2, log_every=100)
            Trainer(model, cfg, t1).run()
            t2 = TrainerConfig(steps=6, batch=2, seq=16, ckpt_dir=d,
                               ckpt_interval=2, log_every=100)
            _, hist = Trainer(model, cfg, t2).run()
            assert hist[0]["step"] == 4  # resumed, not restarted


class TestOptim:
    def test_adamw_decreases_quadratic(self):
        params = {"w": jnp.ones((8,)) * 5.0}
        opt = adamw_init(params)
        lr = cosine_schedule(0.5, 0, 100)
        for _ in range(50):
            g = {"w": 2 * params["w"]}
            params, opt, _ = adamw_update(params, g, opt, lr, weight_decay=0.0)
        assert float(jnp.abs(params["w"]).max()) < 1.0

    def test_int8_roundtrip_error_bounded(self):
        x = jax.random.normal(KEY, (1000,)) * 3
        q, s = quantize_int8(x)
        x2 = dequantize_int8(q, s, x.shape, x.dtype)
        assert float(jnp.max(jnp.abs(x - x2))) < float(jnp.max(jnp.abs(x))) / 64


class TestData:
    def test_deterministic_and_step_addressable(self):
        cfg = get_config("smollm-360m").tiny()
        s1 = SyntheticStream(cfg, 32, 4, seed=1)
        s2 = SyntheticStream(cfg, 32, 4, seed=1)
        b1, b2 = s1.batch_at(10), s2.batch_at(10)
        np.testing.assert_array_equal(b1.tokens, b2.tokens)
        assert not np.array_equal(s1.batch_at(11).tokens, b1.tokens)

    def test_labels_are_shifted_tokens(self):
        cfg = get_config("smollm-360m").tiny()
        b = SyntheticStream(cfg, 32, 4, seed=1).batch_at(0)
        np.testing.assert_array_equal(b.tokens[:, 1:], b.labels[:, :-1])

    def test_vlm_labels_masked_on_patches(self):
        cfg = get_config("internvl2-76b").tiny()
        b = SyntheticStream(cfg, 32, 2, seed=0).batch_at(0)
        assert (b.labels[:, :cfg.num_patches] == -1).all()
        assert b.extra_embeds.shape == (2, cfg.num_patches, cfg.d_model)


class TestLoss:
    def test_xent_matches_manual(self):
        from repro.train import softmax_xent
        logits = jax.random.normal(KEY, (2, 4, 16))
        labels = jax.random.randint(KEY, (2, 4), 0, 10)
        loss, m = softmax_xent(logits, labels, vocab_size=10)
        ref = -jax.nn.log_softmax(logits[..., :10], -1)
        ref = jnp.take_along_axis(ref, labels[..., None], -1).mean()
        np.testing.assert_allclose(float(loss), float(ref), rtol=1e-5)

    def test_masked_labels_excluded(self):
        from repro.train import softmax_xent
        logits = jax.random.normal(KEY, (1, 4, 16))
        labels = jnp.array([[2, -1, 3, -1]])
        loss, m = softmax_xent(logits, labels, vocab_size=10)
        assert float(m["tokens"]) == 2


class TestServing:
    def test_generate_greedy_deterministic(self):
        from repro.serve import ServeEngine
        cfg = get_config("smollm-360m").tiny().replace(remat=False)
        model = build_model(cfg)
        params = model.init(KEY)
        eng = ServeEngine(model, cfg)
        prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
        out1 = eng.generate(params, prompts, max_new=4)
        out2 = eng.generate(params, prompts, max_new=4)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        assert out1.shape == (2, 4)

    def test_partitioned_batcher_learns(self):
        from repro.serve import PartitionedBatcher, ReplicaGroup
        from repro.sim import Channel, ClusterSim
        sim = ClusterSim([Channel(10.0, 0.5), Channel(30.0, 5.0)], seed=0)
        b = PartitionedBatcher([ReplicaGroup("a"), ReplicaGroup("b")], sim=sim)
        for _ in range(40):
            b.run_batch(np.zeros((32, 4), np.int32))
        counts = b.split(32)
        assert counts[0] > counts[1]  # fast replica gets more requests
        assert counts.sum() == 32


@pytest.mark.slow
class TestPartitionedTrainStep:
    def test_variable_pod_microsteps(self):
        """Run in a subprocess-free way: 1-device mesh with pod axis size 1
        exercises the shard_map code path; multi-device variant is covered by
        the dry-run."""
        from repro.launch.mesh import make_local_mesh
        from repro.models.transformer import ShardCtx
        from repro.train.step import init_state, make_partitioned_train_step

        cfg = get_config("smollm-360m").tiny().replace(remat=False)
        mesh = make_local_mesh(("pod", "data", "model"))
        model = build_model(cfg, ShardCtx(mesh=mesh, batch_axes=("data",)))
        state = init_state(model, KEY)
        step = jax.jit(make_partitioned_train_step(
            model, cfg, mesh, cosine_schedule(1e-3, 2, 10), max_micro=3))
        tokens = np.random.default_rng(0).integers(
            0, cfg.vocab_size, (3, 2, 16)).astype(np.int32)
        k = jnp.array([2], jnp.int32)
        state2, metrics = step(state, jnp.asarray(tokens), jnp.asarray(tokens), k)
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["tokens"]) == 2 * 2 * 16  # 2 microsteps x 2 x 16
