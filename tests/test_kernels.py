"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.frontier_grid import frontier_grid
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 2, 1, 128, 64), (2, 4, 2, 256, 64), (1, 8, 8, 128, 128),
    (1, 2, 2, 512, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None), (True, 64)])
def test_flash_attention_sweep(B, Hq, Hkv, S, D, dtype, causal, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_k=128, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_flash_attention_rectangular_cross():
    """Cross-attention: Sq != Sk, non-causal."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 4, 256, 64))
    k = jax.random.normal(ks[1], (2, 4, 128, 64))
    v = jax.random.normal(ks[2], (2, 4, 128, 64))
    out = flash_attention(q, k, v, causal=False, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, expect, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 128, 2, 16, 1, 32, 64), (2, 256, 4, 32, 2, 64, 128),
    (1, 64, 2, 16, 1, 32, 64), (1, 128, 4, 8, 1, 16, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, S, H, P, G, N, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.5).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, S, G, N)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, G, N)) * 0.3).astype(dtype)
    Dk = jnp.ones((H,)) * 0.5
    out = ssd_scan(x, dt, A, Bm, Cm, Dk, chunk=chunk, interpret=True)
    expect = ref.ssd_scan_ref(x, dt, A, Bm, Cm, Dk)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=0.05 if dtype == jnp.bfloat16 else 5e-4,
                               rtol=0.05 if dtype == jnp.bfloat16 else 5e-4)


def test_ssd_xla_chunked_matches_ref():
    ks = jax.random.split(KEY, 5)
    B, S, H, P, G, N = 1, 256, 2, 16, 1, 32
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    Dk = jnp.ones((H,)) * 0.5
    out = ops.ssd(x, dt, A, Bm, Cm, Dk, impl="xla", chunk=64)
    expect = ref.ssd_scan_ref(x, dt, A, Bm, Cm, Dk)
    np.testing.assert_allclose(out, expect, atol=5e-5, rtol=5e-4)
    # final-state return path matches, incl. a non-divisible length (padding)
    y, state = ops.ssd(x, dt, A, Bm, Cm, Dk, impl="xla", chunk=64,
                       return_final_state=True)
    assert state.shape == (B, H, P, N)
    np.testing.assert_allclose(y, expect, atol=5e-5, rtol=5e-4)
    out_odd = ops.ssd(x[:, :200], dt[:, :200], A, Bm[:, :200], Cm[:, :200],
                      Dk, impl="xla", chunk=64)
    expect_odd = ref.ssd_scan_ref(x[:, :200], dt[:, :200], A, Bm[:, :200],
                                  Cm[:, :200], Dk)
    np.testing.assert_allclose(out_odd, expect_odd, atol=5e-5, rtol=5e-4)


@pytest.mark.parametrize("rows,D", [(64, 96), (17, 128), (256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rows, D, dtype):
    x = jax.random.normal(KEY, (rows, D), dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (D,), dtype)
    out = rmsnorm(x, w, interpret=True)
    expect = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("F,K,num_t", [(128, 2, 512), (256, 8, 512), (64, 16, 256)])
def test_frontier_grid_sweep(F, K, num_t):
    W = jax.random.dirichlet(KEY, jnp.ones((K,)), (F,))
    mus = jax.random.uniform(jax.random.fold_in(KEY, 1), (K,), minval=10, maxval=40)
    sgs = jax.random.uniform(jax.random.fold_in(KEY, 2), (K,), minval=0.5, maxval=6)
    m1, v1 = frontier_grid(W, mus, sgs, num_t=num_t, block_f=64, interpret=True)
    m2, v2 = ref.frontier_grid_ref(W, mus, sgs, num_t=num_t)
    np.testing.assert_allclose(m1, m2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(v1, v2, atol=1e-3, rtol=1e-2)


def test_frontier_grid_matches_core_oracle():
    """Kernel semantics == repro.core.max_moments_quad on the same split."""
    from repro.core import max_moments_quad
    W = jnp.array([[0.4, 0.6]])
    mus, sgs = jnp.array([30.0, 20.0]), jnp.array([2.0, 6.0])
    mk, vk = ops.frontier_moments(jnp.tile(W, (64, 1)), mus, sgs,
                                  num_t=2048, impl="pallas_interpret")
    mq, vq = max_moments_quad(W[0] * mus, W[0] * sgs, num=2048)
    np.testing.assert_allclose(mk[0], mq, rtol=1e-4)
    np.testing.assert_allclose(vk[0], vq, rtol=1e-3)


def test_chunked_xla_attention_long():
    """Scan-over-chunks path == dense ref, incl. SWA band slicing."""
    ks = jax.random.split(KEY, 3)
    B, H, S, D = 1, 2, 2048, 32
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    for window in (None, 256):
        out = ops.attention(q, k, v, causal=True, window=window, impl="xla",
                            xla_q_chunk=512)
        expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, expect, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("B,Hkv,G,S,D,block", [
    (1, 2, 4, 512, 64, 128), (2, 4, 1, 1024, 64, 512), (1, 1, 8, 256, 128, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, Hkv, G, S, D, block, dtype):
    from repro.kernels.flash_decode import flash_decode
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hkv, G, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    valid = jnp.arange(S) < (S - S // 4)   # partially filled cache
    out = flash_decode(q, k, v, valid, block_s=block, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_flash_decode_matches_model_local_decode():
    """Kernel semantics == the model's decode attention path."""
    from repro.kernels.flash_decode import flash_decode
    from repro.models.attention import _local_decode
    ks = jax.random.split(KEY, 3)
    B, Hkv, G, S, D = 2, 2, 3, 256, 32
    q4 = jax.random.normal(ks[0], (B, Hkv, G, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    valid = jnp.arange(S) < 200
    out = flash_decode(q4, k, v, valid, block_s=64, interpret=True)
    expect = _local_decode(q4.reshape(B, Hkv * G, D), k, v, valid, G)
    np.testing.assert_allclose(np.asarray(out.reshape(B, Hkv * G, D)),
                               np.asarray(expect), atol=2e-4, rtol=2e-4)
