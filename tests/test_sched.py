"""Scheduler integration: balancer, straggler policy, elasticity, simulator."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to fixed-seed sweeps (see requirements-dev.txt)
    from _hypothesis_fallback import given, settings, st

from repro.sched import StragglerPolicy, UncertaintyAwareBalancer, integerize
from repro.sim import Channel, ClusterSim


class TestIntegerize:
    @settings(max_examples=50, deadline=None)
    @given(st.integers(1, 8), st.integers(1, 500), st.integers(0, 10_000))
    def test_property_sums_to_total(self, k, total, seed):
        rng = np.random.default_rng(seed)
        w = rng.dirichlet(np.ones(k))
        counts = integerize(w, total)
        assert counts.sum() == total
        assert (counts >= 0).all()

    def test_largest_remainder(self):
        counts = integerize(np.array([0.5, 0.3, 0.2]), 10)
        assert list(counts) == [5, 3, 2]


class TestBalancer:
    def test_learns_and_shifts_work(self):
        """Feed a fast/stable + slow/noisy channel; the frontier policy must
        give the fast channel more work."""
        sim = ClusterSim([Channel(mu=10.0, sigma=0.5),
                          Channel(mu=30.0, sigma=6.0)], seed=1)
        b = UncertaintyAwareBalancer(2, lam=0.01)
        for _ in range(60):
            w = b.weights()
            _, durs = sim.run_step(w)
            b.observe(durs, w)
        mus, _ = b.estimates()
        assert abs(mus[0] - 10.0) < 2.0 and abs(mus[1] - 30.0) < 5.0
        w = b.weights()
        assert w[0] > w[1]

    def test_policies_differ(self):
        b = UncertaintyAwareBalancer(2, policy="equal")
        np.testing.assert_allclose(b.weights(), [0.5, 0.5])
        b2 = UncertaintyAwareBalancer(2, policy="frontier")
        b2.observe([10.0, 30.0], [1.0, 1.0])
        b2.observe([10.5, 28.0], [1.0, 1.0])
        assert b2.weights()[0] > 0.5

    def test_frontier_beats_equal_split_in_simulation(self):
        """End-to-end on the paper's Fig-1 channels. Note f=0.5 happens to BE
        the min-variance split for this pair (paper Fig 1b), so the honest
        claims are: a speed-leaning frontier (small lam) beats equal on MEAN,
        and a certainty-leaning frontier (large lam) matches equal's variance
        while improving the mean — i.e. equal split is dominated."""
        def run(policy, lam, seed=3):
            sim = ClusterSim([Channel(mu=30.0, sigma=2.0),
                              Channel(mu=20.0, sigma=6.0)], seed=seed)
            b = UncertaintyAwareBalancer(2, lam=lam, policy=policy)
            times = []
            for i in range(300):
                w = b.weights()
                t, durs = sim.run_step(w)
                b.observe(durs, w)
                if i >= 50:  # after burn-in
                    times.append(t)
            return np.mean(times), np.var(times)

        mu_e, var_e = run("equal", 0.05)
        mu_fast, _ = run("frontier", 0.05)
        assert mu_fast < mu_e                      # speed-leaning: faster
        mu_safe, var_safe = run("frontier", 5.0)
        assert mu_safe < mu_e                      # still faster than equal
        assert var_safe < var_e * 2.0              # without blowing up variance

    def test_state_dict_roundtrip(self):
        b = UncertaintyAwareBalancer(3, lam=0.1)
        b.observe([10.0, 20.0, 30.0], [1.0, 1.0, 1.0])
        b2 = UncertaintyAwareBalancer.from_state_dict(b.state_dict())
        np.testing.assert_allclose(b.weights(), b2.weights(), atol=1e-6)

    def test_elastic_add_remove(self):
        b = UncertaintyAwareBalancer(2)
        b.observe([10.0, 20.0], [1.0, 1.0])
        b.add_channel()
        assert b.num_channels == 3
        assert len(b.weights()) == 3
        b.remove_channel(1)
        assert b.num_channels == 2
        assert abs(b.weights().sum() - 1.0) < 1e-6


class TestStraggler:
    def test_acute_straggler_flagged_and_quarantined(self):
        b = UncertaintyAwareBalancer(2)
        pol = StragglerPolicy(b, z_threshold=2.5, quarantine_after=2)
        for _ in range(30):  # learn normal behaviour
            pol.record([10.0, 12.0], [0.5, 0.5])
        flagged = []
        for _ in range(3):  # channel 1 degrades 5x
            flagged = pol.record([10.0, 60.0], [0.5, 0.5])
        assert 1 in flagged
        assert 1 in pol.quarantined
        w = pol.weights()
        assert w[1] == 0.0 and abs(w.sum() - 1.0) < 1e-9

    def test_probation_restores_channel(self):
        b = UncertaintyAwareBalancer(2)
        pol = StragglerPolicy(b, z_threshold=2.0, quarantine_after=1,
                              probation_period=5)
        for _ in range(20):
            pol.record([10.0, 12.0], [0.5, 0.5])
        pol.record([10.0, 80.0], [0.5, 0.5])
        assert 1 in pol.quarantined
        for _ in range(6):
            pol.record([10.0, 12.0], [0.5, 0.5])
        assert 1 not in pol.quarantined

    def test_hard_failure_removes_channel(self):
        b = UncertaintyAwareBalancer(3)
        pol = StragglerPolicy(b)
        pol.fail(1)
        assert b.num_channels == 2
        assert len(pol.weights()) == 2


class TestSimulator:
    def test_reproducible(self):
        s1 = ClusterSim.heterogeneous(4, seed=7)
        s2 = ClusterSim.heterogeneous(4, seed=7)
        t1, d1 = s1.run_step([0.25] * 4)
        t2, d2 = s2.run_step([0.25] * 4)
        assert t1 == t2
        np.testing.assert_allclose(d1, d2)

    def test_join_time_is_max(self):
        sim = ClusterSim([Channel(10, 0.1), Channel(20, 0.1)], seed=0)
        t, durs = sim.run_step([0.5, 0.5])
        assert t == durs.max()

    def test_failure_injection(self):
        sim = ClusterSim([Channel(10, 0.1), Channel(20, 0.1)], seed=0)
        sim.inject_failure(0)
        _, durs = sim.run_step([0.5, 0.5])
        assert durs[0] == 0.0
