"""Unit + property tests for the paper's core: max-stat moments, frontier,
partitioner, Bayesian estimation, group selection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to fixed-seed sweeps (see requirements-dev.txt)
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    clark_max_moments_2, clark_max_moments_seq, equal_split,
    frontier_2ch, inverse_mu_split, max_moments_mc, max_moments_quad,
    nig_init, nig_point_estimates, nig_update, nig_update_batch,
    optimize_2ch, optimize_weights, pareto_mask, predict_moments,
    select_channels, select_channels_exhaustive, select_on_frontier,
)

PARAMS = st.tuples(
    st.floats(5.0, 100.0), st.floats(0.1, 10.0),
    st.floats(5.0, 100.0), st.floats(0.1, 10.0),
)


class TestMaxMoments:
    def test_clark_exact_matches_quad(self):
        m, v = clark_max_moments_2(30.0, 2.0, 20.0, 6.0)
        qm, qv = max_moments_quad(jnp.array([30.0, 20.0]), jnp.array([2.0, 6.0]),
                                  num=4096)
        np.testing.assert_allclose(m, qm, rtol=1e-4)
        np.testing.assert_allclose(v, qv, rtol=1e-3)

    @pytest.mark.mc_oracle
    def test_against_monte_carlo(self):
        means = jnp.array([30.0, 20.0, 25.0])
        stds = jnp.array([2.0, 6.0, 1.0])
        qm, qv = max_moments_quad(means, stds, num=4096)
        mm, mv = max_moments_mc(jax.random.PRNGKey(0), means, stds,
                                num_samples=400_000)
        np.testing.assert_allclose(qm, mm, rtol=2e-3)
        np.testing.assert_allclose(qv, mv, rtol=3e-2)

    def test_single_channel_degenerates_to_normal(self):
        m, v = max_moments_quad(jnp.array([25.0]), jnp.array([3.0]), num=4096)
        np.testing.assert_allclose(m, 25.0, rtol=1e-3)
        np.testing.assert_allclose(v, 9.0, rtol=1e-2)

    def test_zero_work_channel_drops_out(self):
        m1, v1 = max_moments_quad(jnp.array([20.0, 0.0]), jnp.array([2.0, 0.0]),
                                  num=4096)
        m2, v2 = max_moments_quad(jnp.array([20.0]), jnp.array([2.0]), num=4096)
        np.testing.assert_allclose(m1, m2, rtol=1e-4)
        np.testing.assert_allclose(v1, v2, rtol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(PARAMS)
    def test_property_max_mean_geq_each(self, p):
        """E[max(X,Y)] >= max(E X, E Y) — Jensen for the max."""
        m1, s1, m2, s2 = p
        m, _ = clark_max_moments_2(jnp.float32(m1), jnp.float32(s1),
                                   jnp.float32(m2), jnp.float32(s2))
        assert float(m) >= max(m1, m2) - 1e-3

    @settings(max_examples=25, deadline=None)
    @given(PARAMS)
    def test_property_seq_clark_close_to_oracle(self, p):
        m1, s1, m2, s2 = p
        means = jnp.array([m1, m2, (m1 + m2) / 2], jnp.float32)
        stds = jnp.array([s1, s2, (s1 + s2) / 2], jnp.float32)
        cm, cv = clark_max_moments_seq(means, stds)
        qm, qv = max_moments_quad(means, stds, num=4096)
        assert abs(float(cm) - float(qm)) / float(qm) < 0.05

    @settings(max_examples=20, deadline=None)
    @given(PARAMS, st.floats(0.05, 0.95))
    def test_property_partition_scaling(self, p, f):
        """T_i ~ N(f mu, (f sigma)^2): moments scale as the paper assumes.

        Valid-regime property: the survival integral runs over t >= 0, i.e.
        it computes moments of max(T, 0). For mu >> sigma (the paper's own
        regime — its Fig 5 data has CoV ~ 0.1) the truncation is negligible;
        hypothesis found that at CoV ~ 0.6 it is not, which is a boundary of
        the paper's Normal model, not of the implementation. We pin the
        property to CoV <= 1/4 where truncation error < 1e-4 relative.
        """
        m1, s1, m2, s2 = p
        # CoV in [1/100, 1/4]: above, the t>=0 truncation bites (model
        # boundary); below, the fixed 4096-pt trapezoid grid under-resolves
        # sigma (numerics boundary: ~40 grid points per sigma at CoV 1/100).
        s1 = float(np.clip(s1, m1 / 100.0, m1 / 4.0))
        m, v = max_moments_quad(jnp.array([f * m1]), jnp.array([f * s1]),
                                num=4096)
        np.testing.assert_allclose(m, f * m1, rtol=2e-3)
        np.testing.assert_allclose(v, (f * s1) ** 2, rtol=2e-2)


class TestFrontier:
    def test_paper_figure1_reproduction(self):
        """Fig 1 params: minima below both single channels, at different f."""
        res = frontier_2ch(30.0, 2.0, 20.0, 6.0, num_f=101)
        i_mu, i_var = np.argmin(res.mu), np.argmin(res.var)
        # single-channel values: f=0 -> channel j alone (mu 20, var 36)
        assert res.mu[i_mu] < 20.0 * 0.75          # much faster than best single
        assert res.var[i_var] < 4.0                # var below best single (2^2)
        assert i_mu != i_var                       # paper: different optima -> range
        assert res.efficient.sum() >= 2            # a frontier, not a point

    def test_pareto_mask_correct(self):
        mu = np.array([1.0, 2.0, 3.0, 1.5])
        var = np.array([3.0, 1.0, 0.5, 4.0])
        eff = pareto_mask(mu, var)
        assert list(eff) == [True, True, True, False]

    def test_select_on_frontier_lambda_tradeoff(self):
        res = frontier_2ch(30.0, 2.0, 20.0, 6.0, num_f=101)
        _, (f0, mu0, var0) = select_on_frontier(res, lam=0.0)
        _, (f1, mu1, var1) = select_on_frontier(res, lam=10.0)
        assert mu0 <= mu1 + 1e-6
        assert var1 <= var0 + 1e-6


class TestPartitioner:
    def test_2ch_beats_single_and_equal(self):
        dec = optimize_2ch(30.0, 2.0, 20.0, 6.0)
        assert dec.mu < 20.0
        eq_mu, eq_var = predict_moments(np.array([0.5, 0.5]),
                                        np.array([30.0, 20.0]),
                                        np.array([2.0, 6.0]))
        assert dec.mu <= eq_mu + 1e-6

    def test_weights_on_simplex(self):
        dec = optimize_weights(np.array([30.0, 20.0, 25.0]),
                               np.array([2.0, 6.0, 3.0]), lam=0.1, restarts=1)
        assert np.all(dec.weights >= -1e-9)
        np.testing.assert_allclose(dec.weights.sum(), 1.0, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(2, 5), st.integers(0, 1000))
    def test_property_optimized_no_worse_than_baselines(self, k, seed):
        rng = np.random.default_rng(seed)
        mus = rng.uniform(10, 40, k)
        sigmas = mus * rng.uniform(0.02, 0.25, k)
        dec = optimize_weights(mus, sigmas, lam=0.0, steps=120, restarts=1)
        for w in (equal_split(k), inverse_mu_split(mus)):
            base_mu, _ = predict_moments(np.asarray(w), mus, sigmas)
            assert dec.mu <= base_mu * 1.02  # within 2% slack of any baseline

    def test_partition_beats_fastest_single_channel(self):
        """The paper's headline claim."""
        mus, sigmas = np.array([30.0, 20.0]), np.array([2.0, 6.0])
        dec = optimize_2ch(*mus.repeat(1)[[0]], sigmas[0], mus[1], sigmas[1])
        assert dec.mu < mus.min()
        assert dec.var < (sigmas.min()) ** 2 * 2


class TestBayes:
    def test_posterior_concentrates_on_truth(self):
        rng = np.random.default_rng(0)
        true_mu, true_sigma = 22.0, 3.0
        state = nig_init(1, m0=10.0)
        for _ in range(400):
            obs = rng.normal(true_mu, true_sigma)
            state = nig_update_batch(state, jnp.array([obs], jnp.float32),
                                     jnp.array([1.0], jnp.float32))
        mu_hat, sigma_hat = nig_point_estimates(state)
        assert abs(float(mu_hat[0]) - true_mu) < 0.5
        assert abs(float(sigma_hat[0]) - true_sigma) < 0.8

    def test_masked_channels_unchanged(self):
        state = nig_init(3)
        s2 = nig_update_batch(state, jnp.array([5.0, 7.0, 9.0]),
                              jnp.array([1.0, 0.0, 1.0]))
        assert float(s2.kappa[1]) == float(state.kappa[1])
        assert float(s2.m[1]) == float(state.m[1])

    @settings(max_examples=20, deadline=None)
    @given(st.floats(1.0, 50.0), st.integers(1, 50))
    def test_property_kappa_monotone(self, rate, n):
        state = nig_init(1)
        for _ in range(n):
            state = nig_update(state, jnp.array(0), jnp.float32(rate))
        assert float(state.kappa[0]) > n - 1
        # with near-constant observations the mean estimate approaches rate
        mu_hat, _ = nig_point_estimates(state)
        if n > 10:
            assert abs(float(mu_hat[0]) - rate) < max(0.2 * rate, 0.5)


class TestGroupSelection:
    def test_greedy_matches_exhaustive_small(self):
        mus = [30.0, 20.0, 28.0, 45.0]
        sigmas = [2.0, 6.0, 3.0, 1.0]
        g = select_channels(mus, sigmas, lam=0.1, join_cost=0.5, pgd_steps=80)
        e = select_channels_exhaustive(mus, sigmas, lam=0.1, join_cost=0.5,
                                       pgd_steps=80)
        assert g.objective <= e.objective * 1.1  # greedy within 10% of oracle

    def test_join_cost_limits_k(self):
        mus = [20.0] * 6
        sigmas = [2.0] * 6
        cheap = select_channels(mus, sigmas, join_cost=0.0, pgd_steps=60)
        costly = select_channels(mus, sigmas, join_cost=5.0, pgd_steps=60)
        assert len(costly.indices) <= len(cheap.indices)

    def test_failure_aware_admission_excludes_flaky_fast_channel(self):
        """Under the defective family the enlistment term charges expected
        ATTEMPTS (join_cost / (1 - p)): the fastest channel buys its way in
        while reliable and is priced out once flaky."""
        from repro.core.distributions import Defective

        mus = [10.0, 12.0, 12.5, 13.0]      # channel 0 fastest...
        sigmas = [1.0, 1.2, 1.2, 1.3]
        reliable = select_channels(
            mus, sigmas, lam=0.05, join_cost=1.0, pgd_steps=60,
            family=Defective(p=[0.0, 0.0, 0.0, 0.0]))
        flaky = select_channels(
            mus, sigmas, lam=0.05, join_cost=1.0, pgd_steps=60,
            family=Defective(p=[0.6, 0.0, 0.0, 0.0]))   # ...but flaky
        assert 0 in reliable.indices.tolist()
        assert 0 not in flaky.indices.tolist()
        # retries also inflate the objective the selection reports
        assert flaky.objective > reliable.objective

    def test_failure_aware_greedy_matches_exhaustive(self):
        from repro.core.distributions import Defective

        fam = Defective(p=[0.5, 0.0, 0.3, 0.0])
        mus = [11.0, 14.0, 12.0, 16.0]
        sigmas = [1.0, 1.5, 1.1, 1.8]
        g = select_channels(mus, sigmas, lam=0.05, join_cost=0.8,
                            pgd_steps=60, family=fam)
        e = select_channels_exhaustive(mus, sigmas, lam=0.05, join_cost=0.8,
                                       pgd_steps=60, family=fam)
        assert sorted(g.indices.tolist()) == sorted(e.indices.tolist())
        assert g.objective == pytest.approx(e.objective, rel=1e-6)

    def test_always_up_families_charge_plain_join_cost(self):
        """Attempt pricing reduces to the classic join_cost * k for families
        without failure physics, and a p=0 defective fleet matches it."""
        from repro.core.distributions import Defective

        mus = [20.0, 24.0, 28.0]
        sigmas = [2.0, 2.4, 2.8]
        normal = select_channels(mus, sigmas, lam=0.05, join_cost=1.5,
                                 pgd_steps=60)
        zero_p = select_channels(mus, sigmas, lam=0.05, join_cost=1.5,
                                 pgd_steps=60, family=Defective(p=0.0))
        assert sorted(normal.indices.tolist()) == \
            sorted(zero_p.indices.tolist())
        assert normal.objective == pytest.approx(zero_p.objective, rel=1e-5)
