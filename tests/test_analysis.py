"""The repro.analysis tier: lint framework + every rule (bad fixture fires,
good fixture stays silent), the CLI, pragma suppression, the self-clean
gate on the real source tree, and the REPRO_SANITIZE runtime sanitizer."""
import json
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.analysis import format_json, format_text, run_paths, rule_codes
from repro.analysis import sanitize as san


def _lint(tmp_path, source, select=None, name="fx.py"):
    """Write one fixture module and lint it; returns the findings."""
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    return run_paths([str(p)], select=select)


def _codes(findings):
    return sorted(f.code for f in findings)


# ---------------------------------------------------------------------------
# framework mechanics
# ---------------------------------------------------------------------------
class TestFramework:
    def test_unparseable_file_is_a_finding(self, tmp_path):
        fs = _lint(tmp_path, "def broken(:\n")
        assert _codes(fs) == ["RPA000"]

    def test_every_rule_declares_unique_codes(self):
        codes = rule_codes()
        assert len(codes) >= 13  # the PR 6 rule set
        assert all(c.startswith("RPA") for c in codes)

    def test_findings_sort_and_format(self, tmp_path):
        fs = _lint(tmp_path, """
            def f(mus, sigmas):
                return mus + sigmas
            """)
        assert fs == sorted(fs)
        line = fs[0].format()
        assert "RPA001" in line and str(fs[0].line) in line

    def test_json_reporter_round_trips(self, tmp_path):
        fs = _lint(tmp_path, """
            def f(mus, sigmas):
                return mus
            """)
        data = json.loads(format_json(fs))
        assert data["count"] == len(fs)
        assert data["findings"][0]["code"] == "RPA001"
        assert "RPA001" in format_text(fs)

    def test_pragma_on_line_suppresses(self, tmp_path):
        fs = _lint(tmp_path, """
            def f(mus, sigmas):  # repro: allow[RPA001] fixture
                return mus
            """)
        assert fs == []

    def test_pragma_block_above_suppresses(self, tmp_path):
        fs = _lint(tmp_path, """
            # this helper is family-agnostic by design
            # repro: allow[RPA001] fixture justification
            def f(mus, sigmas):
                return mus
            """)
        assert fs == []

    def test_pragma_only_silences_named_code(self, tmp_path):
        fs = _lint(tmp_path, """
            def f(mus, sigmas):  # repro: allow[RPA050] wrong code
                return mus
            """)
        assert _codes(fs) == ["RPA001"]

    def test_select_filters(self, tmp_path):
        fs = _lint(tmp_path, """
            def f(mus, sigmas):
                return mus
            """, select=["RPA050"])
        assert fs == []

    def test_cli_exit_codes_and_json(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text("def f(mus, sigmas):\n    return mus\n")
        root = pathlib.Path(__file__).resolve().parents[1]
        env_src = str(root / "src")
        r = subprocess.run(
            [sys.executable, "-m", "repro.analysis", str(bad), "--json"],
            capture_output=True, text=True, env={"PYTHONPATH": env_src,
                                                 "PATH": "/usr/bin:/bin"})
        assert r.returncode == 1
        assert json.loads(r.stdout)["findings"][0]["code"] == "RPA001"
        good = tmp_path / "good.py"
        good.write_text("x = 1\n")
        r = subprocess.run(
            [sys.executable, "-m", "repro.analysis", str(good)],
            capture_output=True, text=True, env={"PYTHONPATH": env_src,
                                                 "PATH": "/usr/bin:/bin"})
        assert r.returncode == 0


# ---------------------------------------------------------------------------
# per-rule: bad fixture fires, good fixture silent
# ---------------------------------------------------------------------------
class TestFamilyThreading:
    def test_rpa001_fires(self, tmp_path):
        fs = _lint(tmp_path, """
            def score(mus, sigmas, lam):
                return mus + lam * sigmas
            """)
        assert "RPA001" in _codes(fs)

    def test_rpa001_silent_with_family(self, tmp_path):
        fs = _lint(tmp_path, """
            def score(mus, sigmas, lam, family="normal"):
                return mus + lam * sigmas

            def score2(mus, sigmas, dist_id="normal"):
                return mus
            """)
        assert fs == []

    def test_rpa002_fires_on_dropped_family(self, tmp_path):
        fs = _lint(tmp_path, """
            def inner(mus, sigmas, family="normal"):
                return mus

            def outer(mus, sigmas, family="normal"):
                return inner(mus, sigmas)
            """)
        assert "RPA002" in _codes(fs)

    def test_rpa002_silent_when_forwarded(self, tmp_path):
        fs = _lint(tmp_path, """
            def inner(mus, sigmas, family="normal"):
                return mus

            def outer(mus, sigmas, family="normal"):
                return inner(mus, sigmas, family=family)
            """)
        assert fs == []


_VJP_GOOD = """
    import jax
    import functools

    @functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
    def prim(x, y, n):
        return x * y

    def prim_fwd(x, y, n):
        return x * y, (x, y)

    def prim_bwd(n, res, ct):
        '''Zero y-cotangent is deliberate: y is a stop-gradient input.'''
        x, y = res
        return ct * y, ct * x

    prim.defvjp(prim_fwd, prim_bwd)
    """


class TestCustomVjpContract:
    def test_rpa010_fires_without_defvjp(self, tmp_path):
        fs = _lint(tmp_path, """
            import jax

            @jax.custom_vjp
            def prim(x, y):
                return x * y
            """)
        assert "RPA010" in _codes(fs)

    def test_rpa011_fires_on_cotangent_arity(self, tmp_path):
        fs = _lint(tmp_path, """
            import jax
            import functools

            @functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
            def prim(x, y, n):
                return x * y

            def prim_fwd(x, y, n):
                return x * y, (x, y)

            def prim_bwd(n, res, ct):
                x, y = res
                return (ct * y,)

            prim.defvjp(prim_fwd, prim_bwd)
            """)
        assert "RPA011" in _codes(fs)

    def test_rpa012_fires_on_residual_mismatch(self, tmp_path):
        fs = _lint(tmp_path, """
            import jax

            @jax.custom_vjp
            def prim(x, y):
                return x * y

            def prim_fwd(x, y):
                return x * y, (x, y, x + y)

            def prim_bwd(res, ct):
                x, y = res
                return ct * y, ct * x

            prim.defvjp(prim_fwd, prim_bwd)
            """)
        assert "RPA012" in _codes(fs)

    def test_good_vjp_silent(self, tmp_path):
        assert _lint(tmp_path, _VJP_GOOD) == []


class TestStaticArgs:
    def test_rpa020_fires_on_traced_branch(self, tmp_path):
        fs = _lint(tmp_path, """
            import jax
            import functools

            @functools.partial(jax.jit, static_argnames=("n",))
            def f(x, n, mode):
                if mode:
                    return x * n
                return x
            """)
        assert "RPA020" in _codes(fs)

    def test_rpa021_fires_on_self_mutation(self, tmp_path):
        fs = _lint(tmp_path, """
            import jax

            class A:
                @jax.jit
                def f(self, x):
                    self.cache = x
                    return x
            """)
        assert "RPA021" in _codes(fs)

    def test_rpa022_fires_on_stale_static_name(self, tmp_path):
        fs = _lint(tmp_path, """
            import jax
            import functools

            @functools.partial(jax.jit, static_argnames=("gone",))
            def f(x, n):
                return x * n
            """)
        assert "RPA022" in _codes(fs)

    def test_good_static_usage_silent(self, tmp_path):
        fs = _lint(tmp_path, """
            import jax
            import functools

            @functools.partial(jax.jit, static_argnames=("mode", "n"))
            def f(x, n, mode):
                if mode:
                    return x * n
                return x
            """)
        assert fs == []


_PALLAS_WRAPPER = """
    import functools
    import jax
    from jax.experimental import pallas as pl

    def kernel(w_ref, out_ref):
        out_ref[...] = w_ref[...]

    def launch(W, num_t: int = 1024, block_f: int = {block_f}{extra_param}):
        F, K = W.shape
        {guard}
        return pl.pallas_call(
            kernel,
            grid=(F // block_f,),
            out_shape=jax.ShapeDtypeStruct((F,), W.dtype),
        )(W)
    """


class TestVmemAudit:
    def test_rpa030_fires_on_pgrad_overflow(self, tmp_path):
        # 256 overflows the 12 MiB budget for EVERY grad/pgrad family combo
        # at the K=1024/T=1024 audit point — the acceptance-criteria case
        src = _PALLAS_WRAPPER.format(
            block_f=256, extra_param=", param_grads: bool = False",
            guard="if F % block_f:\n            raise ValueError(F)")
        fs = _lint(tmp_path, src)
        assert "RPA030" in _codes(fs)
        msg = next(f for f in fs if f.code == "RPA030").message
        assert "pgrad" in msg and "64" in msg  # largest safe fused block

    def test_rpa030_silent_on_safe_fwd_default(self, tmp_path):
        src = _PALLAS_WRAPPER.format(
            block_f=128, extra_param="",
            guard="if F % block_f:\n            raise ValueError(F)")
        assert _lint(tmp_path, src) == []

    def test_rpa031_fires_without_divisibility_guard(self, tmp_path):
        src = _PALLAS_WRAPPER.format(block_f=128, extra_param="", guard="pass")
        fs = _lint(tmp_path, src)
        assert "RPA031" in _codes(fs)

    def test_real_defaults_match_the_budget_model(self):
        """The shipped kernel defaults must sit inside the same budget the
        lint rule audits: 128 fits every fwd combo, 64 every fused one."""
        from repro.core.distributions import FAMILIES
        from repro.kernels import autotune

        for dist_id in FAMILIES:
            for stacked in (False, True):
                assert autotune.vmem_bytes(128, 1024, 1024, fused=False,
                                           dist_id=dist_id, stacked=stacked) \
                    <= autotune._VMEM_BUDGET_BYTES
                for params in (False, True):
                    assert autotune.vmem_bytes(64, 1024, 1024, fused=True,
                                               dist_id=dist_id, params=params,
                                               stacked=stacked) \
                        <= autotune._VMEM_BUDGET_BYTES


class TestContracts:
    def test_rpa040_fires_on_undocumented_zero_cotangent(self, tmp_path):
        fs = _lint(tmp_path, """
            import jax.numpy as jnp

            def prim_bwd(res, ct):
                x, y = res
                return ct * y, jnp.zeros_like(x)
            """)
        assert "RPA040" in _codes(fs)

    def test_rpa040_silent_when_documented(self, tmp_path):
        fs = _lint(tmp_path, """
            import jax.numpy as jnp

            def prim_bwd(res, ct):
                '''y gets a zero cotangent: it is a stop-gradient constant.'''
                x, y = res
                return ct * y, jnp.zeros_like(x)
            """)
        assert fs == []

    def test_rpa050_fires_on_every_spelling(self, tmp_path):
        fs = _lint(tmp_path, """
            import repro.core.normal
            from repro.core.normal import Phi
            from repro.core import normal
            """)
        assert _codes(fs).count("RPA050") == 3

    def test_rpa050_silent_on_distributions(self, tmp_path):
        fs = _lint(tmp_path, """
            from repro.core.distributions import Phi, safe_cdf
            """)
        assert fs == []


class TestFidelityKnob:
    def test_rpa070_fires_on_literal_num_t(self, tmp_path):
        fs = _lint(tmp_path, """
            from repro.kernels import ops

            def f(W, mus, sigmas, family):
                return ops.frontier_moments(W, mus, sigmas, num_t=2048,
                                            family=family)
            """, select=["RPA070"])
        assert _codes(fs) == ["RPA070"]

    def test_rpa070_fires_on_constant_arithmetic(self, tmp_path):
        fs = _lint(tmp_path, """
            from repro.kernels import ops

            def f(W, mus, sigmas, family):
                return ops.frontier_moments_with_grads(
                    W, mus, sigmas, num_t=2 * 1024, family=family)
            """, select=["RPA070"])
        assert _codes(fs) == ["RPA070"]

    def test_rpa070_silent_when_threaded(self, tmp_path):
        fs = _lint(tmp_path, """
            from repro.kernels import ops

            def f(W, mus, sigmas, family, num_t):
                return ops.frontier_moments(W, mus, sigmas, num_t=num_t,
                                            family=family)
            """, select=["RPA070"])
        assert fs == []

    def test_rpa070_pragma_suppresses(self, tmp_path):
        fs = _lint(tmp_path, """
            from repro.kernels import ops

            def f(W, mus, sigmas, family):
                # repro: allow[RPA070] figure reproduction at pinned rung
                return ops.frontier_moments(W, mus, sigmas, num_t=2048,
                                            family=family)
            """, select=["RPA070"])
        assert fs == []

    def test_rpa070_tests_dir_exempt(self, tmp_path):
        import textwrap

        from repro.analysis import run_paths
        d = tmp_path / "tests"
        d.mkdir()
        (d / "test_fx.py").write_text(textwrap.dedent("""
            from repro.kernels import ops

            def test_f(W, mus, sigmas, family):
                return ops.frontier_moments(W, mus, sigmas, num_t=128,
                                            family=family)
            """))
        fs = run_paths([str(d)], select=["RPA070"])
        assert fs == []


def _lint_serve(tmp_path, source, select=("RPA080",), subdir="serve"):
    """Write one fixture under ``<tmp>/<subdir>/`` and lint it — RPA080
    only patrols files whose path contains a ``serve`` directory."""
    d = tmp_path / subdir
    d.mkdir()
    p = d / "engine_fx.py"
    p.write_text(textwrap.dedent(source))
    return run_paths([str(p)], select=list(select))


_PER_INSTANCE_LOOP = """
    from repro.kernels import ops

    def tick(instances, num_t):
        out = []
        for inst in instances:
            out.append(ops.frontier_moments_with_grads(
                inst.W, inst.mus, inst.sigmas, num_t=num_t,
                family=inst.family))
        return out
    """


class TestServingBatchDiscipline:
    def test_rpa080_fires_on_per_instance_loop(self, tmp_path):
        fs = _lint_serve(tmp_path, _PER_INSTANCE_LOOP)
        assert _codes(fs) == ["RPA080"]

    def test_rpa080_fires_in_comprehension(self, tmp_path):
        fs = _lint_serve(tmp_path, """
            from repro.kernels import ops

            def tick(instances, num_t):
                return [ops.frontier_moments(i.W, i.mus, i.sigmas,
                                             num_t=num_t, family=i.family)
                        for i in instances]
            """)
        assert _codes(fs) == ["RPA080"]

    def test_rpa080_silent_outside_serve_dir(self, tmp_path):
        # the identical per-instance loop is legal off the serving path
        # (e.g. a benchmark's documented looped baseline)
        fs = _lint(tmp_path, _PER_INSTANCE_LOOP, select=["RPA080"])
        assert fs == []

    def test_rpa080_silent_for_stacked_launch(self, tmp_path):
        # the batched idiom: the per-FAMILY-GROUP loop calls the stacked
        # helper, and the kernel entry point sits at top level
        fs = _lint_serve(tmp_path, """
            from repro.kernels import ops

            def row_step(W, mus, sigmas, fam, num_t):
                return ops.frontier_moments_with_grads(
                    W, mus, sigmas, num_t=num_t, family=fam)

            def tick(groups, num_t):
                return [row_step(g.W, g.mus, g.sigmas, g.fam, num_t)
                        for g in groups]
            """)
        assert fs == []

    def test_rpa080_tests_dir_exempt(self, tmp_path):
        d = tmp_path / "serve" / "tests"
        d.mkdir(parents=True)
        p = d / "test_fx.py"
        p.write_text(textwrap.dedent(_PER_INSTANCE_LOOP))
        assert run_paths([str(p)], select=["RPA080"]) == []

    def test_rpa080_pragma_suppresses(self, tmp_path):
        fs = _lint_serve(tmp_path, """
            from repro.kernels import ops

            def tick(instances, num_t):
                out = []
                for inst in instances:
                    # repro: allow[RPA080] documented migration shim
                    out.append(ops.frontier_moments(
                        inst.W, inst.mus, inst.sigmas, num_t=num_t,
                        family=inst.family))
                return out
            """)
        assert fs == []


# ---------------------------------------------------------------------------
# the gate: the real tree lints clean
# ---------------------------------------------------------------------------
class TestSelfClean:
    def test_source_tree_lints_clean(self):
        root = pathlib.Path(__file__).resolve().parents[1]
        fs = run_paths([str(root / "src")])
        assert fs == [], "\n" + format_text(fs)


# ---------------------------------------------------------------------------
# runtime sanitizer
# ---------------------------------------------------------------------------
class TestSanitizerEager:
    @pytest.fixture
    def on(self, monkeypatch):
        monkeypatch.setenv(san.ENV_VAR, "1")

    def _problem(self):
        W = np.asarray([[0.5, 0.3, 0.2]], np.float32)
        mus = np.asarray([10.0, 20.0, 30.0], np.float32)
        sgs = np.asarray([1.0, 2.0, 3.0], np.float32)
        return W, mus, sgs

    def test_disabled_by_default(self, monkeypatch):
        monkeypatch.delenv(san.ENV_VAR, raising=False)
        assert not san.enabled()

    def test_nan_weight_caught_only_when_enabled(self, on, monkeypatch):
        from repro.kernels import ops

        W, mus, sgs = self._problem()
        W_bad = W.copy()
        W_bad[0, 0] = np.nan
        with pytest.raises(san.SanitizeError, match="non-finite"):
            ops.frontier_moments(W_bad, mus, sgs, num_t=128)
        # the unsanitized path silently propagates the NaN into the moments
        monkeypatch.delenv(san.ENV_VAR)
        mu, _ = ops.frontier_moments(W_bad, mus, sgs, num_t=128)
        assert np.isnan(float(mu[0]))

    def test_off_simplex_weight_caught(self, on, monkeypatch):
        from repro.kernels import ops

        W, mus, sgs = self._problem()
        W_bad = W * 2.0  # row mass 2: every downstream moment silently scales
        with pytest.raises(san.SanitizeError, match="row mass"):
            ops.frontier_moments(W_bad, mus, sgs, num_t=128)
        monkeypatch.delenv(san.ENV_VAR)
        mu, _ = ops.frontier_moments(W_bad, mus, sgs, num_t=128)
        assert np.isfinite(float(mu[0]))  # silent wrong answer without tier

    def test_negative_sigma_caught(self, on):
        from repro.kernels import ops

        W, mus, sgs = self._problem()
        with pytest.raises(san.SanitizeError, match="nonneg"):
            ops.frontier_moments(W, mus, -sgs, num_t=128)

    def test_fold_inputs_checked(self, on):
        from repro.core.maxstat import clark_max_moments_seq

        with pytest.raises(san.SanitizeError, match="non-finite"):
            clark_max_moments_seq(np.asarray([1.0, np.nan]),
                                  np.asarray([0.1, 0.1]))

    def test_grads_entry_point_checked(self, on):
        from repro.kernels import ops

        W, mus, sgs = self._problem()
        bad_mus = mus.copy()
        bad_mus[1] = np.inf
        with pytest.raises(san.SanitizeError, match="mus"):
            ops.frontier_moments_with_grads(W, bad_mus, sgs, num_t=128)

    def test_clean_inputs_pass_and_match_unsanitized(self, on, monkeypatch):
        from repro.kernels import ops

        W, mus, sgs = self._problem()
        mu1, var1 = ops.frontier_moments(W, mus, sgs, num_t=128)
        monkeypatch.delenv(san.ENV_VAR)
        mu0, var0 = ops.frontier_moments(W, mus, sgs, num_t=128)
        np.testing.assert_allclose(np.asarray(mu1), np.asarray(mu0))
        np.testing.assert_allclose(np.asarray(var1), np.asarray(var0))


@pytest.mark.sanitizer
class TestSanitizerCheckify:
    """In-trace checkify tier: retraces the solvers, so scripts/ci.sh --fast
    skips these (the --full sanitizer pass runs them)."""

    @pytest.fixture
    def on(self, monkeypatch):
        monkeypatch.setenv(san.ENV_VAR, "1")

    def test_pgd_catches_nan_lam(self, on):
        from jax.experimental.checkify import JaxRuntimeError

        from repro.core.partitioner import optimize_weights

        mus = np.asarray([10.0, 20.0, 30.0], np.float32)
        sgs = np.asarray([1.0, 2.0, 3.0], np.float32)
        with pytest.raises(JaxRuntimeError, match="non-finite"):
            optimize_weights(mus, sgs, lam=float("nan"), steps=4,
                             num_t=128, restarts=0)

    def test_pgd_clean_solve_matches_unsanitized(self, on, monkeypatch):
        from repro.core.partitioner import optimize_weights

        mus = np.asarray([10.0, 20.0, 30.0], np.float32)
        sgs = np.asarray([1.0, 2.0, 3.0], np.float32)
        d1 = optimize_weights(mus, sgs, lam=0.1, steps=8, num_t=128,
                              restarts=1)
        monkeypatch.delenv(san.ENV_VAR)
        d0 = optimize_weights(mus, sgs, lam=0.1, steps=8, num_t=128,
                              restarts=1)
        np.testing.assert_allclose(d1.weights, d0.weights, atol=1e-6)

    def test_dag_solver_catches_nan_lam_var(self, on):
        from jax.experimental.checkify import JaxRuntimeError

        from repro.workflow.dag import Stage, StageDAG
        from repro.workflow.solve import solve_dag

        def mk(name, k, seed):
            r = np.random.default_rng(seed)
            mus = r.uniform(10, 40, k)
            return Stage(name, mus, mus * r.uniform(0.1, 0.4, k))

        dag = StageDAG([mk("a", 3, 0), mk("b", 2, 1)], [("a", "b")])

        with pytest.raises(JaxRuntimeError, match="non-finite"):
            solve_dag(dag, lam_var=float("nan"), steps=4, num_t=128,
                      restarts=0)


# ---------------------------------------------------------------------------
# RPA090/RPA091: observability discipline
# ---------------------------------------------------------------------------
def _lint_repro(tmp_path, source, select, subdir="repro"):
    """Write one fixture under ``<tmp>/repro/`` — RPA090/RPA091 only
    patrol files whose path contains a ``repro`` directory."""
    d = tmp_path / subdir
    d.mkdir(parents=True, exist_ok=True)
    p = d / "mod_fx.py"
    p.write_text(textwrap.dedent(source))
    return run_paths([str(p)], select=list(select))


_FREE_NAME_EMIT = """
    from repro.obs import trace as obs

    def tick():
        with obs.span("engine.my_new_span", rows=3):
            pass
        obs.event("audit.surprise", cause="drift")
    """

_REGISTRY_EMIT = """
    from repro.obs import names as obs_names
    from repro.obs import trace as obs

    def tick():
        with obs.span(obs_names.SPAN_ENGINE_TICK, rows=3):
            pass
        obs.event(obs_names.EV_DIRTY, cause="drift")
    """


class TestObservabilityDiscipline:
    def test_rpa090_fires_on_free_string_names(self, tmp_path):
        fs = _lint_repro(tmp_path, _FREE_NAME_EMIT, select=("RPA090",))
        assert _codes(fs) == ["RPA090", "RPA090"]
        assert "repro.obs.names" in fs[0].message

    def test_rpa090_silent_on_registry_constants(self, tmp_path):
        assert _lint_repro(tmp_path, _REGISTRY_EMIT,
                           select=("RPA090",)) == []

    def test_rpa090_ignores_unrelated_event_calls(self, tmp_path):
        # a sim's own event queue is not an obs emit site
        assert _lint_repro(tmp_path, """
            def drain(queue):
                queue.event("fired", at=3)

            def local():
                def event(name):
                    return name
                return event("fine")
            """, select=("RPA090",)) == []

    def test_rpa090_exempts_obs_package_and_outside_repro(self, tmp_path):
        assert _lint_repro(tmp_path, _FREE_NAME_EMIT, select=("RPA090",),
                           subdir="repro/obs") == []
        assert _lint(tmp_path, _FREE_NAME_EMIT, select=["RPA090"]) == []

    def test_rpa091_bans_wall_clock_in_repro(self, tmp_path):
        fs = _lint_repro(tmp_path, """
            import time

            def span():
                t0 = time.time()
                return time.time() - t0
            """, select=("RPA091",))
        assert _codes(fs) == ["RPA091", "RPA091"]
        assert "perf_counter" in fs[0].message

    def test_rpa091_allows_monotonic_and_pragma(self, tmp_path):
        assert _lint_repro(tmp_path, """
            import time

            def span():
                t0 = time.perf_counter()
                return time.perf_counter() - t0
            """, select=("RPA091",)) == []
        assert _lint_repro(tmp_path, """
            import time

            def artifact_name():
                # repro: allow[RPA091] artifact date stamp, not a duration
                return int(time.time())
            """, select=("RPA091",)) == []

    def test_rpa091_silent_outside_repro(self, tmp_path):
        assert _lint(tmp_path, """
            import time

            def now():
                return time.time()
            """, select=["RPA091"]) == []
