"""The fused moments+gradient path: analytic adjoints vs autodiff through the
quadrature graph, the ``frontier_moments`` custom VJP, the fused Pallas kernel
vs its oracle, and the block_f autotune cache."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partitioner import objective, optimize_weights
from repro.kernels import autotune, ops, ref
from repro.kernels.frontier_grid import frontier_grid_with_grads


def _problem(k, seed=0, cov=(0.05, 0.3)):
    rng = np.random.default_rng(seed)
    mus = rng.uniform(10, 40, k).astype(np.float32)
    sigmas = (mus * rng.uniform(*cov, k)).astype(np.float32)
    return jnp.asarray(mus), jnp.asarray(sigmas)


def _candidates(F, k, seed=0):
    rng = np.random.default_rng(seed)
    e = rng.exponential(size=(F, k))
    return jnp.asarray(e / e.sum(axis=1, keepdims=True), jnp.float32)


# repro: allow[RPA001] deliberately normal-only autodiff oracle: family
# parity is covered per-dist_id by TestFamilyGradParity below
def _autodiff_grads(W, mus, sigmas, num_t):
    """Per-row (dmu_dW, dvar_dW) by jax.grad through the OLD quadrature
    objective (rows are independent, so grad-of-sum is the per-row grad)."""
    dmu = jax.grad(lambda W: jnp.sum(
        ref.frontier_grid_ref(W, mus, sigmas, num_t=num_t)[0]))(W)
    dvar = jax.grad(lambda W: jnp.sum(
        ref.frontier_grid_ref(W, mus, sigmas, num_t=num_t)[1]))(W)
    return dmu, dvar


def _rel(a, b):
    """Frobenius-norm relative error (the gradient-parity metric)."""
    a, b = np.asarray(a), np.asarray(b)
    return float(np.linalg.norm(a - b) / np.linalg.norm(b))


class TestGradParity:
    @pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
    @pytest.mark.parametrize("k,F", [(2, 7), (5, 12), (16, 9)])
    def test_analytic_matches_autodiff(self, impl, k, F):
        """Acceptance: fused analytic VJP == jax.grad through the old
        quadrature objective to <= 1e-4 relative, on both backends."""
        mus, sigmas = _problem(k, seed=k)
        W = _candidates(F, k, seed=F)
        num_t = 512
        mu, var, dmu, dvar = ops.frontier_moments_with_grads(
            W, mus, sigmas, num_t=num_t, impl=impl, block_f=4)
        m_ref, v_ref = ref.frontier_grid_ref(W, mus, sigmas, num_t=num_t)
        np.testing.assert_allclose(mu, m_ref, rtol=1e-5)
        np.testing.assert_allclose(var, v_ref, rtol=1e-4, atol=1e-6)
        dmu_a, dvar_a = _autodiff_grads(W, mus, sigmas, num_t)
        assert _rel(dmu, dmu_a) <= 1e-4
        assert _rel(dvar, dvar_a) <= 1e-4

    def test_custom_vjp_routes_through_analytic_path(self):
        """jax.grad of frontier_moments consumes the registered custom VJP —
        identical (bitwise) to the fused kernel's gradient outputs."""
        mus, sigmas = _problem(6, seed=1)
        W = _candidates(10, 6, seed=2)
        g = jax.grad(lambda W: jnp.sum(
            ops.frontier_moments(W, mus, sigmas, num_t=256)[0]))(W)
        _, _, dmu, _ = ops.frontier_moments_with_grads(
            W, mus, sigmas, num_t=256)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(dmu))

    @pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
    def test_objective_grad_matches_old_autodiff(self, impl):
        """The PGD objective's gradient (now analytic) agrees with autodiff
        through the pristine quadrature graph."""
        mus, sigmas = _problem(8, seed=3)
        w = jnp.full((8,), 1.0 / 8)
        lam = 0.07
        g_new = jax.grad(objective)(w, mus, sigmas, lam, 512)
        dmu_a, dvar_a = _autodiff_grads(w[None, :], mus, sigmas, 512)
        g_old = (dmu_a + lam * dvar_a)[0]
        assert _rel(g_new, g_old) <= 1e-4

    def test_zero_weight_and_argmax_edge(self):
        """w_k = 0 channels get zero direct gradient; the argmax channel
        carries the moving-grid (tmax) term — parity must survive both."""
        mus = jnp.asarray([20.0, 20.0, 30.0, 10.0], jnp.float32)
        sigmas = jnp.asarray([5.0, 5.0, 1.0, 2.0], jnp.float32)
        W = jnp.asarray([[0.0, 0.5, 0.25, 0.25],
                         [0.25, 0.25, 0.25, 0.25]], jnp.float32)
        _, _, dmu, dvar = ops.frontier_moments_with_grads(
            W, mus, sigmas, num_t=512)
        dmu_a, dvar_a = _autodiff_grads(W, mus, sigmas, 512)
        assert _rel(dmu, dmu_a) <= 1e-4
        assert _rel(dvar, dvar_a) <= 1e-4
        assert float(dmu[0, 0]) == 0.0  # zero-weight channel, not argmax

    def test_finite_difference_spot_check(self):
        """Central differences on a few coordinates (f32 quadrature => loose
        tolerance; this guards the sign/scale of the adjoint, autodiff parity
        above guards the digits)."""
        k = 5
        mus, sigmas = _problem(k, seed=9)
        w = np.full(k, 1.0 / k, np.float32)
        lam, num_t, eps = 0.05, 1024, 1e-3

        def f(w):
            mu, var = ops.frontier_moments(jnp.asarray(w)[None, :], mus,
                                           sigmas, num_t=num_t)
            return float(mu[0] + lam * var[0])

        _, _, dmu, dvar = ops.frontier_moments_with_grads(
            jnp.asarray(w)[None, :], mus, sigmas, num_t=num_t)
        g = np.asarray(dmu + lam * dvar)[0]
        for i in range(3):
            wp, wm = w.copy(), w.copy()
            wp[i] += eps
            wm[i] -= eps
            fd = (f(wp) - f(wm)) / (2 * eps)
            np.testing.assert_allclose(g[i], fd, rtol=2e-2)

    def test_mus_sigmas_carry_real_cotangents(self):
        """The closed estimation loop: channel-statistic cotangents are no
        longer stop-grads — jax.grad of frontier_moments w.r.t. mus/sigmas
        matches autodiff through the quadrature graph (the full battery,
        families x impls x edges, lives in tests/test_sensitivity.py)."""
        mus, sigmas = _problem(4, seed=5)
        W = _candidates(3, 4)
        gm = jax.grad(lambda m: jnp.sum(
            ops.frontier_moments(W, m, sigmas, num_t=512)[0]))(mus)
        gs = jax.grad(lambda s: jnp.sum(
            ops.frontier_moments(W, mus, s, num_t=512)[1]))(sigmas)
        assert np.any(np.asarray(gm)) and np.any(np.asarray(gs))
        am = jax.grad(lambda m: jnp.sum(
            ref.frontier_grid_ref(W, m, sigmas, num_t=512)[0]))(mus)
        as_ = jax.grad(lambda s: jnp.sum(
            ref.frontier_grid_ref(W, mus, s, num_t=512)[1]))(sigmas)
        assert _rel(gm, am) <= 1e-4
        assert _rel(gs, as_) <= 1e-4


class TestFusedKernel:
    @pytest.mark.parametrize("F,k,bf,num_t", [(8, 5, 4, 256), (12, 16, 4, 512),
                                              (6, 2, 6, 1024)])
    def test_kernel_matches_oracle(self, F, k, bf, num_t):
        mus, sigmas = _problem(k, seed=F)
        W = _candidates(F, k, seed=k)
        outs_k = frontier_grid_with_grads(W, mus, sigmas, num_t=num_t,
                                          block_f=bf, interpret=True)
        outs_r = ref.frontier_grid_with_grads_ref(W, mus, sigmas, num_t=num_t)
        for name, a, b in zip(("mu", "var", "dmu", "dvar"), outs_k, outs_r):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4,
                atol=1e-5 * float(np.max(np.abs(np.asarray(b)))) + 1e-12,
                err_msg=name)

    def test_block_divisibility_is_a_value_error(self):
        """Satellite: a real ValueError (not a -O-stripped assert), carrying
        both values, for callers that bypass ops.py's padding."""
        W = _candidates(6, 3)
        mus, sigmas = _problem(3)
        with pytest.raises(ValueError, match="F=6.*block_f=4"):
            frontier_grid_with_grads(W, mus, sigmas, num_t=64, block_f=4,
                                     interpret=True)

    def test_pgd_consumes_fused_grads_on_both_impls(self):
        """optimize_weights solves THROUGH the fused path under each impl and
        lands on the same weights."""
        mus, sigmas = _problem(6, seed=11)
        decs = {impl: optimize_weights(mus, sigmas, lam=0.05, steps=80,
                                       restarts=0, impl=impl)
                for impl in ("xla", "pallas_interpret")}
        np.testing.assert_allclose(decs["pallas_interpret"].weights,
                                   decs["xla"].weights, atol=1e-3)


class TestAutotuneCache:
    def test_cache_round_trip(self, tmp_path):
        """Sweep -> JSON -> fresh process (cleared in-process cache) -> lookup
        returns the swept winner, not the model pick."""
        path = str(tmp_path / "autotune_cache.json")
        entry = autotune.sweep(8, 3, 64, backend="xla", repeats=1,
                               candidates=(4, 8), cache_path=path)
        assert entry["source"] == "sweep" and entry["block_f"] in (4, 8)
        on_disk = json.load(open(path))
        key = "v3:xla:F8:K3:T64:modefwd:famnormal"
        assert on_disk[key]["block_f"] == entry["block_f"]
        autotune.clear_cache()
        assert autotune.lookup(8, 3, 64, backend="xla",
                               cache_path=path) == entry["block_f"]
        autotune.clear_cache()  # leave no tmp-path state for other tests

    def test_model_prefers_smaller_blocks_for_fused(self):
        """The fused kernel's ~3x accumulator footprint must shrink the
        model's pick at fleet scale (the PR 1 block_f=128 regression guard)."""
        fwd = autotune.pick_block_f(4096, 1024, 256, backend="pallas",
                                    fused=False)
        fused = autotune.pick_block_f(4096, 1024, 256, backend="pallas",
                                      fused=True)
        assert fused <= fwd
        assert autotune.vmem_bytes(fused, 1024, 256, fused=True) \
            <= int(16 * 1024 * 1024 * 0.75)

    def test_unconstrained_shapes_autotune_silently(self):
        """block_f=None end-to-end: frontier_moments resolves a launch shape
        from the cache/model and matches the explicit-block_f result."""
        mus, sigmas = _problem(5, seed=7)
        W = _candidates(40, 5)
        mu_a, var_a = ops.frontier_moments(W, mus, sigmas, num_t=128)
        mu_e, var_e = ops.frontier_moments(W, mus, sigmas, num_t=128,
                                           block_f=8)
        np.testing.assert_allclose(mu_a, mu_e, rtol=1e-5)
        # var re-fuses differently per launch shape; f32 cancellation noise
        np.testing.assert_allclose(var_a, var_e, rtol=2e-4, atol=1e-6)
