"""Fault-tolerance tier (the ``fault`` marker; scripts/ci.sh runs it under
REPRO_SANITIZE=1 on every tier).

Acceptance anchors:
  * kill/restore tick parity — a balancer / workflow balancer / serving
    batcher rebuilt from a ``save_pipeline`` manifest produces a next tick
    BITWISE identical to the survivor's (ckpt/store.py's contract);
  * churn schedules (fail / throttle / recover mid-trace) flow from the sim
    into the deciders: a failed channel draws zero share on the next tick
    and is re-admitted after recovery;
  * ``resolve_inflight`` prices sunk work: dead channels get exactly zero,
    finished jobs solve to zero, and a firm adaptive-refresh solve skips
    the PGD (the warm start IS the answer);
  * checkpoint robustness — corrupt/empty/missing LATEST pointers fall back
    to the newest complete step, and template/checkpoint divergence raises
    a ValueError naming the leaf and both shapes (the old bare assert
    vanished under ``python -O``);
  * the chaos harness composes all of the above and verifies parity
    continuously.
"""
import json
import os

import numpy as np
import pytest

from repro.ckpt import (CheckpointManager, latest_step, restore,
                        restore_pipeline, save, save_pipeline)
from repro.kernels import autotune
from repro.sched import StragglerPolicy, UncertaintyAwareBalancer
from repro.sched.balancer import WorkflowBalancer
from repro.sim import Channel, ClusterSim
from repro.sim.chaos import run_chaos_trace
from repro.sim.cluster import WorkflowSim
from repro.workflow.dag import Stage, StageDAG, linear_edges

pytestmark = pytest.mark.fault


def _seeded_balancer(k=4, seed=0, **kw):
    kw.setdefault("lam", 0.05)
    kw.setdefault("pgd_steps", 40)
    kw.setdefault("explore", 0.0)
    b = UncertaintyAwareBalancer(num_channels=k, **kw)
    rng = np.random.default_rng(seed)
    for _ in range(5):
        b.observe(rng.uniform(8, 30, k), np.full(k, 1.0 / k))
    return b


def _dag(k=3):
    rng = np.random.default_rng(7)
    stages = [Stage("a", rng.uniform(10, 30, k), rng.uniform(1, 4, k)),
              Stage("b", rng.uniform(10, 30, k), rng.uniform(1, 4, k))]
    return StageDAG(stages, linear_edges(["a", "b"]))


def _engine_templates():
    """Two mixed-family templates for the serving-engine fault tests."""
    wf = StageDAG([
        Stage("a", mus=[1.0, 1.5], sigmas=[0.2, 0.3]),
        Stage("b", mus=[2.0, 2.6, 3.2], sigmas=[0.3, 0.4, 0.5]),
    ], edges=linear_edges(["a", "b"]))
    fan = StageDAG([
        Stage("src", mus=[1.2, 1.7], sigmas=[0.25, 0.3],
              family="lognormal"),
        Stage("left", mus=[2.1, 2.8], sigmas=[0.4, 0.5],
              family="lognormal"),
        Stage("right", mus=[1.9, 2.5], sigmas=[0.35, 0.45],
              family="lognormal"),
    ], edges=[("src", "left"), ("src", "right")])
    return {"wf": wf, "fan": fan}


def _seeded_workflow_balancer(dag, seed=0, **kw):
    kw.setdefault("pgd_steps", 30)
    wb = WorkflowBalancer(dag=dag, **kw)
    rng = np.random.default_rng(seed)
    w = {s.name: np.full(s.k, 1.0 / s.k) for s in dag.stages}
    for _ in range(4):
        wb.observe({s.name: rng.uniform(8, 30, s.k) for s in dag.stages}, w)
    return wb


class TestKillRestoreParity:
    """ckpt/store.py's contract: the restored replica's next tick is
    bitwise identical to the survivor's."""

    def test_balancer_tick_parity(self, tmp_path):
        b = _seeded_balancer()
        save_pipeline(str(tmp_path), 3, b)
        w_survivor = b.weights()
        b2, inflight, meta = restore_pipeline(str(tmp_path))
        assert inflight is None and meta["step"] == 3
        np.testing.assert_array_equal(w_survivor, b2.weights())
        # posteriors came along too: the tick after next also agrees
        obs = np.array([12.0, 25.0, 18.0, 30.0])
        b.observe(obs, w_survivor)
        b2.observe(obs, w_survivor)
        np.testing.assert_array_equal(b.weights(), b2.weights())

    def test_workflow_balancer_tick_parity(self, tmp_path):
        dag = _dag()
        wb = _seeded_workflow_balancer(dag)
        wb.handle_failure("a", 1)   # failure sets must survive the crash too
        save_pipeline(str(tmp_path), 1, wb)
        w_survivor = wb.weights()
        wb2, _, _ = restore_pipeline(str(tmp_path), dag=dag)
        w_replica = wb2.weights()
        assert wb2.failed_channels() == {"a": [1]}
        for n in w_survivor:
            np.testing.assert_array_equal(w_survivor[n], w_replica[n])
        assert w_replica["a"][1] == 0.0

    def test_workflow_kind_requires_dag(self, tmp_path):
        save_pipeline(str(tmp_path), 1, _seeded_workflow_balancer(_dag()))
        with pytest.raises(ValueError, match="dag"):
            restore_pipeline(str(tmp_path))

    def test_partitioned_batcher_tick_parity(self, tmp_path):
        from repro.serve.engine import PartitionedBatcher, ReplicaGroup

        groups = [ReplicaGroup(name=f"g{i}") for i in range(3)]
        pb = PartitionedBatcher(groups, lam=0.02, seed=5)
        prompts = np.zeros((18, 4), np.int32)
        for _ in range(2):
            pb.run_batch(prompts)
        # the manifest carries the balancer; the sim world rides inflight
        save_pipeline(str(tmp_path), 2, pb.balancer,
                      inflight={"sim": pb.sim.state_dict()})
        join_sv, counts_sv, _ = pb.run_batch(prompts)
        bal2, inflight, _ = restore_pipeline(str(tmp_path))
        pb2 = PartitionedBatcher(groups)
        pb2.balancer = bal2
        pb2.sim = ClusterSim.from_state_dict(inflight["sim"])
        join_rp, counts_rp, _ = pb2.run_batch(prompts)
        assert join_sv == join_rp
        np.testing.assert_array_equal(counts_sv, counts_rp)

    def test_workflow_engine_kill_restore_tick_parity(self, tmp_path):
        """Engine-level kill/restore through the PR 7 manifest with
        instances IN FLIGHT: the restored engine's next tick — admissions,
        stacked solves, per-instance splits, retirements — is bitwise
        identical to the survivor's."""
        from repro.serve import WorkflowEngine

        templates = _engine_templates()
        eng = WorkflowEngine(templates, max_live=4, settle_steps=2,
                             num_t=128, seed=7)
        for i in range(6):   # more than max_live: the queue rides too
            eng.submit("wf" if i % 2 else "fan", deadline=6.0)
        eng.tick()
        assert eng.live_count > 0          # mid-flight, not a cold engine
        assert eng.queue_depth > 0         # backpressured requests ride too
        save_pipeline(str(tmp_path), eng.tick_count, eng)
        survivor = eng.tick()              # the would-be survivor's tick
        eng2, _, _ = restore_pipeline(str(tmp_path), templates=templates)
        replica = eng2.tick()
        assert survivor == replica
        for iid, inst in eng._live.items():
            for name, w in inst.weights.items():
                np.testing.assert_array_equal(
                    w, eng2._live[iid].weights[name])

    def test_engine_kind_checkpoint_needs_templates(self, tmp_path):
        from repro.serve import WorkflowEngine

        eng = WorkflowEngine(_engine_templates(), num_t=128)
        save_pipeline(str(tmp_path), 1, eng)
        with pytest.raises(ValueError, match="templates"):
            restore_pipeline(str(tmp_path))

    def test_workflow_sim_churn_schedule(self):
        dag = StageDAG([
            Stage("s1", mus=[10.0, 14.0], sigmas=[1.0, 1.5]),
            Stage("s2", mus=[12.0, 16.0], sigmas=[1.2, 1.8]),
        ], edges=linear_edges(["s1", "s2"]))
        sim = WorkflowSim.from_dag(dag, seed=0)
        sim.schedule_churn(2, "fail", stage="s1", idx=0)
        sim.schedule_churn(2, "set_load", value=1.5)    # stage=None: all
        sim.schedule_churn(3, "recover", stage="s1", idx=0)
        sim.tick()
        assert not sim.stage_sims["s1"].channels[0].failed
        sim.tick()          # step 2: the fail and the broadcast load fire
        assert sim.stage_sims["s1"].channels[0].failed
        assert all(s.load_factor == 1.5 for s in sim.stage_sims.values())
        sim.tick()
        assert not sim.stage_sims["s1"].channels[0].failed
        with pytest.raises(ValueError, match="action"):
            sim.schedule_churn(1, "explode")
        with pytest.raises(ValueError, match="stage"):
            sim.schedule_churn(1, "fail", idx=0)        # fail needs a stage
        with pytest.raises(ValueError, match="value"):
            sim.schedule_churn(1, "throttle", stage="s1", idx=0)

    def test_workflow_sim_state_round_trip_with_pending_churn(self):
        dag = StageDAG([Stage("s", mus=[10.0, 14.0], sigmas=[1.0, 1.5])])
        sim = WorkflowSim.from_dag(dag, seed=3)
        sim.schedule_churn(2, "throttle", stage="s", idx=1, value=2.0)
        sim.tick()
        sim2 = WorkflowSim.from_state_dict(sim.state_dict())
        m1, _, d1 = sim.run_dag_step(dag, {"s": np.array([0.6, 0.4])})
        m2, _, d2 = sim2.run_dag_step(dag, {"s": np.array([0.6, 0.4])})
        assert m1 == m2                    # rng stream AND churn both rode
        np.testing.assert_array_equal(d1["s"], d2["s"])
        # the pending throttle fired at step 2 in BOTH worlds (mu doubled)
        assert sim.stage_sims["s"].channels[1].mu == pytest.approx(28.0)
        assert sim2.stage_sims["s"].channels[1].mu == pytest.approx(28.0)

    def test_workflow_chaos_trace_parity(self):
        from repro.sim.chaos import run_workflow_chaos_trace

        dag = StageDAG([
            Stage("s1", mus=[10.0, 14.0, 18.0], sigmas=[1.0, 1.5, 2.0]),
            Stage("s2", mus=[12.0, 16.0], sigmas=[1.2, 1.8]),
        ], edges=linear_edges(["s1", "s2"]))
        res = run_workflow_chaos_trace(
            dag, ticks=6, kill_every=3, seed=1,
            churn=[(2, "fail", "s1", 0, None),
                   (5, "recover", "s1", 0, None)],
            verify_parity=True)
        assert res.kills == 1 and res.parity_checks == 1
        assert len(res.joins) == 6 and all(j > 0 for j in res.joins)
        assert res.final_failed == []      # recovered before the end

    def test_chaos_trace_verifies_parity_continuously(self):
        res = run_chaos_trace(num_channels=5, ticks=9, kill_every=3,
                              churn=[(4, "fail", 1), (7, "recover", 1)],
                              seed=2, verify_parity=True)
        assert res.kills == 2 and res.parity_checks == 2
        assert len(res.joins) == 9 and all(j > 0 for j in res.joins)
        assert res.final_failed == []       # recovered before the end
        s = res.summary()
        assert s["parity_checks"] == 2 and s["mean_join"] > 0

    def test_chaos_trace_defective_fleet(self):
        """Crash cycles + retry physics: the geometric retry draws ride the
        snapshotted rng stream, so parity holds for defective fleets too."""
        res = run_chaos_trace(num_channels=4, ticks=6, kill_every=2,
                              dist="defective", seed=3, verify_parity=True)
        assert res.kills == 2 and res.parity_checks == 2


class TestChurnSchedules:
    def test_fail_then_recover_round_trip(self):
        sim = ClusterSim.heterogeneous(3, seed=1)
        sim.schedule_churn(2, "fail", 1)
        sim.schedule_churn(3, "recover", 1)
        w = np.full(3, 1.0 / 3)
        _, d1 = sim.run_step(w)
        assert (d1 > 0).all()
        _, d2 = sim.run_step(w)            # event fires BEFORE the draws
        assert d2[1] == 0.0 and d2[0] > 0 and d2[2] > 0
        _, d3 = sim.run_step(w)
        assert (d3 > 0).all()

    def test_throttle_inflates_one_channel(self):
        mk = lambda: ClusterSim([Channel(mu=20.0, sigma=1e-6)
                                 for _ in range(2)], seed=4)
        base = mk()
        slow = mk()
        slow.schedule_churn(1, "throttle", 0, 3.0)
        _, db = base.run_step([0.5, 0.5])
        _, ds = slow.run_step([0.5, 0.5])
        assert ds[0] > 2.0 * db[0]
        np.testing.assert_allclose(ds[1], db[1])

    def test_schedule_churn_validates(self):
        sim = ClusterSim.heterogeneous(2, seed=0)
        with pytest.raises(ValueError, match="action"):
            sim.schedule_churn(1, "explode", 0)
        with pytest.raises(ValueError, match="idx"):
            sim.schedule_churn(1, "fail")
        with pytest.raises(ValueError, match="value"):
            sim.schedule_churn(1, "throttle", 0)

    def test_sim_state_dict_replays_bitwise(self):
        sim = ClusterSim.heterogeneous(4, seed=6, dist="defective")
        sim.schedule_churn(4, "fail", 2)
        w = np.full(4, 0.25)
        for _ in range(2):
            sim.run_step(w)
        clone = ClusterSim.from_state_dict(sim.state_dict())
        for _ in range(3):                  # crosses the queued churn event
            t1, d1 = sim.run_step(w)
            t2, d2 = clone.run_step(w)
            assert t1 == t2
            np.testing.assert_array_equal(d1, d2)
        assert sim.channels[2].failed and clone.channels[2].failed


class TestStragglerSimWiring:
    def _policy(self, k=3, seed=0):
        b = UncertaintyAwareBalancer(k, lam=0.01, pgd_steps=40, explore=0.0)
        pol = StragglerPolicy(b, z_threshold=4.0)
        rng = np.random.default_rng(seed)
        for _ in range(6):
            pol.record(rng.uniform(9, 11, k), np.full(k, 1.0 / k))
        return pol

    def test_soft_fail_zero_weight_then_readmit(self):
        pol = self._policy()
        assert (pol.weights() > 0).all()
        pol.fail(1, remove=False)
        w = pol.weights()
        assert w[1] == 0.0 and abs(w.sum() - 1.0) < 1e-9
        pol.recover(1)
        assert pol.weights()[1] > 0.0      # posterior survived the outage

    def test_fail_propagates_to_bound_sim(self):
        pol = self._policy()
        sim = ClusterSim.heterogeneous(3, seed=2)
        pol.bind_sim(sim)
        pol.fail(2, remove=False)
        assert sim.channels[2].failed
        pol.recover(2)
        assert not sim.channels[2].failed

    def test_sync_with_sim_adopts_churn(self):
        pol = self._policy()
        sim = ClusterSim.heterogeneous(3, seed=2)
        pol.bind_sim(sim)
        sim.inject_failure(0)              # sim-side event the policy missed
        assert pol.sync_with_sim() == {0}
        assert pol.weights()[0] == 0.0
        sim.recover(0)
        assert pol.sync_with_sim() == set()

    def test_sync_without_sim_raises(self):
        with pytest.raises(RuntimeError, match="bind_sim"):
            self._policy().sync_with_sim()

    def test_hard_removal_reindexes_soft_failures(self):
        pol = self._policy(k=4)
        pol.fail(3, remove=False)
        pol.fail(1)                        # hard removal shifts indices down
        assert pol.failed == {2}
        assert len(pol.weights()) == 3


class TestResolveInflight:
    def test_failed_channel_gets_zero_share(self):
        b = _seeded_balancer()
        w = b.weights()
        shares = b.resolve_inflight(0.5 * w, failed=[2])
        assert shares[2] == 0.0
        assert abs(shares.sum() - 1.0) < 1e-6
        assert (shares[np.arange(4) != 2] > 0).all()
        # the steady-state cache is untouched by the mid-flight re-solve
        np.testing.assert_array_equal(b.weights(), w)

    def test_finished_job_solves_to_zero(self):
        b = _seeded_balancer()
        np.testing.assert_array_equal(
            b.resolve_inflight(np.full(4, 0.25)), np.zeros(4))

    def test_no_active_channels_solves_to_zero(self):
        b = _seeded_balancer()
        np.testing.assert_array_equal(
            b.resolve_inflight(np.zeros(4), failed=range(4)), np.zeros(4))

    def test_firm_solve_skips_pgd_and_returns_warm_start(self):
        b = _seeded_balancer(adaptive_refresh=True, refresh_target_rel=1e9)
        w = b.weights()                    # firm by construction of the gate
        assert b._last_rel_fragility is not None
        done = w * np.array([0.5, 0.2, 0.0, 0.1])
        expected = np.maximum(np.asarray(w, np.float64) - done, 0.0)
        expected /= expected.sum()
        np.testing.assert_allclose(b.resolve_inflight(done), expected,
                                   rtol=0, atol=1e-12)

    def test_failure_always_forces_the_solve(self):
        """Losing a channel is a model change, never absorbable drift: even
        a firm solve must re-run the PGD when a channel died."""
        b = _seeded_balancer(adaptive_refresh=True, refresh_target_rel=1e9)
        w = b.weights()
        done = 0.3 * w
        warm = np.maximum(np.asarray(w, np.float64) - done, 0.0)
        warm[1] = 0.0
        warm /= warm.sum()
        shares = b.resolve_inflight(done, failed=[1])
        assert shares[1] == 0.0
        assert not np.array_equal(shares, warm)   # PGD moved off the warm start

    def test_workflow_resolve_inflight_masks_failed(self):
        dag = _dag()
        wb = _seeded_workflow_balancer(dag)
        wb.handle_failure("a", 0)
        out = wb.resolve_inflight({"a": np.full(3, 0.2)})
        assert out["a"][0] == 0.0
        assert abs(out["a"].sum() - 1.0) < 1e-6
        assert abs(out["b"].sum() - 1.0) < 1e-6
        wb.handle_recovery("a", 0)
        assert wb.failed_channels() == {}
        assert wb.weights()["a"][0] > 0.0

    def test_workflow_failure_validates_stage(self):
        wb = _seeded_workflow_balancer(_dag())
        with pytest.raises(KeyError):
            wb.handle_failure("nope", 0)


class TestCheckpointStore:
    def test_missing_leaf_names_the_key(self, tmp_path):
        save(str(tmp_path), 1, {"a": np.zeros(3), "b": np.ones((2, 2))})
        with pytest.raises(ValueError, match=r"leaf 'c' missing"):
            restore(str(tmp_path), {"a": np.zeros(3), "c": np.zeros(2)})

    def test_shape_mismatch_names_leaf_and_shapes(self, tmp_path):
        save(str(tmp_path), 1, {"a": np.zeros(3)})
        with pytest.raises(ValueError, match=r"'a'.*expected \(4,\).*found \(3,\)"):
            restore(str(tmp_path), {"a": np.zeros(4)})

    def test_latest_step_survives_pointer_damage(self, tmp_path):
        d = str(tmp_path)
        save(d, 1, {"x": np.zeros(2)})
        save(d, 2, {"x": np.zeros(2)})
        ptr = os.path.join(d, "LATEST")
        for damage in ("garbage", ""):
            with open(ptr, "w") as f:
                f.write(damage)
            assert latest_step(d) == 2
        os.remove(ptr)
        assert latest_step(d) == 2
        # an in-flight (incomplete) step dir is not a restore candidate
        os.makedirs(os.path.join(d, "step_00000009"))
        assert latest_step(d) == 2
        assert latest_step(str(tmp_path / "nowhere")) is None

    def test_restore_pipeline_requires_manifest(self, tmp_path):
        save(str(tmp_path), 1, {"x": np.zeros(2)})
        with pytest.raises(ValueError, match="pipeline"):
            restore_pipeline(str(tmp_path))

    def test_autotune_cache_rides_the_manifest(self, tmp_path):
        key = autotune._key(8, 3, 64, "xla", False, "defective")
        autotune.clear_cache()
        try:
            autotune._CACHE[key] = {"block_f": 4, "source": "sweep"}
            save_pipeline(str(tmp_path), 1, _seeded_balancer(k=3, seed=1))
            autotune.clear_cache()
            assert key not in autotune.cache_state()
            restore_pipeline(str(tmp_path))
            assert autotune.cache_state()[key]["block_f"] == 4
        finally:
            autotune.clear_cache()

    def test_manifest_carries_inflight_and_model_tree(self, tmp_path):
        b = _seeded_balancer()
        tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
        save_pipeline(str(tmp_path), 5, b,
                      inflight={"done": [0.1, 0.2, 0.0, 0.0]},
                      tree=tree, meta={"note": "mid-flight"})
        b2, inflight, meta = restore_pipeline(
            str(tmp_path), template={"w": np.zeros((2, 3), np.float32)})
        assert inflight == {"done": [0.1, 0.2, 0.0, 0.0]}
        assert meta["note"] == "mid-flight"
        np.testing.assert_array_equal(meta["tree"]["w"], tree["w"])
        np.testing.assert_array_equal(b.weights(), b2.weights())

    def test_manager_maybe_save_pipeline(self, tmp_path):
        b = _seeded_balancer()
        mgr = CheckpointManager(str(tmp_path), interval=2, keep=2)
        saved = [s for s in range(1, 7)
                 if mgr.maybe_save_pipeline(s, b, blocking=True)]
        assert saved == [2, 4, 6]
        assert latest_step(str(tmp_path)) == 6
        kept = [p for p in os.listdir(str(tmp_path)) if p.startswith("step_")]
        assert len(kept) == 2              # bounded retention
        b2, _, _ = restore_pipeline(str(tmp_path))
        np.testing.assert_array_equal(b.weights(), b2.weights())
