"""Observability tier: tracer mechanics, exporters, StreamingStat.merge,
and the zero-perturbation contract (docs/OBSERVABILITY.md).

Acceptance anchors:
  * off-by-default no-op path — with tracing off, ``span`` hands back a
    shared no-op and nothing is recorded;
  * ring-buffer bounds + drop accounting, name-registry rejection at emit
    time (RPA090's runtime half), tick correlation;
  * exporters round-trip: JSONL read/write, schema validation, Perfetto
    ``trace_event`` structure, phase totals, Prometheus text;
  * ``StreamingStat.merge`` equals the concatenated stream on the exact
    moment fields and stays a uniform reservoir on quantiles;
  * zero perturbation — the serving engine and the chaos kill/restore
    harness produce bitwise-identical results traced vs untraced, and a
    restored replica's trace carries the restore event with the manifest
    step (the ``fault``-marked tests ride ci.sh's chaos tier).
"""
import json

import numpy as np
import pytest

from repro.obs import events as obs_events
from repro.obs import export as obs_export
from repro.obs import names as obs_names
from repro.obs import trace as obs
from repro.obs.trace import _NOOP, Tracer
from repro.serve.telemetry import StreamingStat
from repro.workflow.dag import Stage, StageDAG, linear_edges


@pytest.fixture
def tracing():
    """Force-enable the module tracer for one test; restore and clear."""
    prev = obs.enabled()
    obs.clear()
    obs.set_enabled(True)
    yield
    obs.set_enabled(prev)
    obs.set_tick(None)
    obs.clear()


def _dag(k=3, seed=7):
    rng = np.random.default_rng(seed)
    stages = [Stage("a", rng.uniform(10, 30, k), rng.uniform(1, 4, k)),
              Stage("b", rng.uniform(10, 30, k), rng.uniform(1, 4, k))]
    return StageDAG(stages, linear_edges(["a", "b"]))


# ---------------------------------------------------------------------------
# tracer mechanics
# ---------------------------------------------------------------------------
class TestTracer:
    def test_off_by_default_is_noop(self):
        assert not obs.enabled()  # REPRO_TRACE unset in the test env
        sp = obs.span(obs_names.SPAN_SIM_STEP, sim="x")
        assert sp is _NOOP
        with sp:
            pass
        obs.event(obs_names.EV_CHURN, kind="fail")
        obs_events.churn("fail", 0, "test")
        assert obs.records() == []

    def test_timed_span_measures_even_when_off(self):
        assert not obs.enabled()
        with obs.timed_span(obs_names.SPAN_SOLVER_PHASE, phase="p") as sp:
            sum(range(1000))
        assert sp.dur_us > 0.0       # the hand-timer replacement contract
        assert obs.records() == []   # ...but nothing was recorded

    def test_span_records_fields(self, tracing):
        with obs.span(obs_names.SPAN_SIM_STEP, sim="cluster", k=4):
            pass
        (rec,) = obs.records()
        assert rec["type"] == "span"
        assert rec["name"] == obs_names.SPAN_SIM_STEP
        assert rec["dur_us"] >= 0.0
        assert rec["attrs"] == {"sim": "cluster", "k": 4}
        assert isinstance(rec["seq"], int)

    def test_event_and_tick_correlation(self, tracing):
        obs.set_tick(7)
        obs_events.dirty("engine", 3, "drift", 0.125)
        (rec,) = obs.records()
        assert rec["type"] == "event" and rec["tick"] == 7
        assert rec["attrs"] == {"scope": "engine", "key": "3",
                                "cause": "drift", "drift": 0.125}
        assert obs.current_tick() == 7

    def test_unregistered_name_rejected_at_emit(self, tracing):
        with pytest.raises(ValueError, match="unregistered trace name"):
            obs.event("made.up.name", x=1)
        with pytest.raises(ValueError, match="RPA090"):
            with obs.span("also.not.registered"):
                pass

    def test_ring_buffer_drops_oldest_and_counts(self):
        t = Tracer(capacity=8)
        t.set_enabled(True)
        for i in range(20):
            t.event(obs_names.EV_CHURN, i=i)
        recs = t.records()
        assert len(recs) == 8
        assert [r["attrs"]["i"] for r in recs] == list(range(12, 20))
        assert t.dropped() == 12
        t.clear()
        assert t.records() == [] and t.dropped() == 0

    def test_capture_scopes_records_and_restores_state(self, tracing):
        obs.set_enabled(False)
        obs_events.churn("fail", 0, "before")  # off: not recorded
        with obs.capture() as cap:
            assert obs.enabled()
            obs_events.churn("recover", 1, "inside")
        assert not obs.enabled()               # restored to pre-capture
        assert [r["attrs"]["source"] for r in cap] == ["inside"]

    def test_traced_decorator(self, tracing):
        @obs.traced(obs_names.SPAN_SIM_STEP, sim="deco")
        def f(x):
            return x + 1

        assert f(1) == 2
        (rec,) = obs.records()
        assert rec["attrs"] == {"sim": "deco"}
        obs.set_enabled(False)
        obs.clear()
        assert f(2) == 3 and obs.records() == []


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
def _sample_records(tick=3):
    obs.set_tick(tick)
    with obs.span(obs_names.SPAN_SOLVER_PHASE, phase="presolve"):
        pass
    with obs.span(obs_names.SPAN_SOLVER_PHASE, phase="refine"):
        pass
    obs_events.fragility_gate(True, 0.02, 0.1)
    obs_events.ckpt_save(5, "engine", "/tmp/ck")
    return obs.records()


class TestExport:
    def test_jsonl_round_trip(self, tracing, tmp_path):
        recs = _sample_records()
        path = str(tmp_path / "t.jsonl")
        assert obs_export.write_jsonl(recs, path) == len(recs)
        back = obs_export.read_jsonl(path)
        assert back == json.loads(json.dumps(recs))  # same after JSON trip

    def test_validate_accepts_real_records(self, tracing):
        recs = _sample_records()
        assert obs_export.validate_records(recs) == len(recs)
        assert obs_export.span_kinds(recs) == {obs_names.SPAN_SOLVER_PHASE}
        assert obs_export.event_types(recs) == {obs_names.EV_FRAGILITY,
                                                obs_names.EV_CKPT_SAVE}

    def test_validate_rejects_malformed(self, tracing):
        (good,) = [r for r in _sample_records()
                   if r["name"] == obs_names.EV_CKPT_SAVE]

        def bad(**patch):
            return [{**good, **patch}]

        with pytest.raises(ValueError, match="registry"):
            obs_export.validate_records(bad(name="rogue.name"))
        with pytest.raises(ValueError, match="event with a span name"):
            obs_export.validate_records(bad(name=obs_names.SPAN_SIM_STEP))
        with pytest.raises(ValueError, match="bad type"):
            obs_export.validate_records(bad(type="metric"))
        with pytest.raises(ValueError, match="dur_us"):
            obs_export.validate_records(
                bad(type="span", name=obs_names.SPAN_SIM_STEP, dur_us=-1.0))
        with pytest.raises(ValueError, match="attrs"):
            obs_export.validate_records(bad(attrs=None))
        with pytest.raises(ValueError, match="ts_us"):
            obs_export.validate_records(bad(ts_us=None))

    def test_perfetto_structure(self, tracing):
        doc = obs_export.to_perfetto(_sample_records(tick=9))
        json.dumps(doc)  # loadable
        evs = doc["traceEvents"]
        assert evs[0]["ph"] == "M" and evs[0]["name"] == "process_name"
        xs = [e for e in evs if e["ph"] == "X"]
        inst = [e for e in evs if e["ph"] == "i"]
        assert len(xs) == 2 and all(e["dur"] >= 0 for e in xs)
        assert len(inst) == 2 and all(e["s"] == "p" for e in inst)
        assert all(e["args"]["tick"] == 9 for e in xs + inst)
        assert {e["tid"] for e in xs} == {0}  # remapped to small ints

    def test_phase_totals(self, tracing):
        totals = obs_export.phase_totals(_sample_records())
        assert set(totals) == {"presolve", "refine"}
        assert all(v >= 0 for v in totals.values())

    def test_prometheus_snapshot(self, tracing):
        text = obs_export.prometheus_snapshot(_sample_records(), dropped=2)
        assert f'{obs_names.METRIC_SPAN_COUNT}{{kind="solver.phase"}} 2' \
            in text
        assert 'quantile="0.50"' in text
        assert f'{obs_names.METRIC_EVENT_COUNT}' \
               f'{{type="audit.ckpt_save"}} 1' in text
        assert text.rstrip().endswith(f"{obs_names.METRIC_DROPPED} 2")


# ---------------------------------------------------------------------------
# StreamingStat.merge (weighted Welford + reservoir subsample)
# ---------------------------------------------------------------------------
class TestStreamingStatMerge:
    def test_moments_match_concatenated_stream(self):
        rng = np.random.default_rng(0)
        a = rng.normal(5.0, 2.0, 700)
        b = rng.lognormal(1.0, 0.5, 400)
        s1, s2, ground = (StreamingStat(capacity=64) for _ in range(3))
        for x in a:
            s1.add(x)
            ground.add(x)
        for x in b:
            s2.add(x)
            ground.add(x)
        s1.merge(s2)
        assert s1.count == ground.count == 1100
        assert np.isclose(s1.mean(), ground.mean(), rtol=1e-12)
        assert np.isclose(s1.var(), ground.var(), rtol=1e-9)
        assert s1.max() == ground.max() and s1.min() == ground.min()

    def test_reservoir_quantiles_track_concatenated(self):
        rng = np.random.default_rng(1)
        a = rng.uniform(0.0, 1.0, 3000)
        b = rng.uniform(0.0, 2.0, 1000)
        s1 = StreamingStat(capacity=512, seed=3)
        s2 = StreamingStat(capacity=512, seed=4)
        for x in a:
            s1.add(x)
        for x in b:
            s2.add(x)
        s1.merge(s2)
        concat = np.concatenate([a, b])
        for q in (0.25, 0.5, 0.9):
            assert abs(s1.quantile(q) - np.quantile(concat, q)) < 0.15, q
        assert len(s1._res) == 512  # bounded memory survived the merge

    def test_merge_empty_cases(self):
        s1, s2 = StreamingStat(), StreamingStat()
        for x in (1.0, 2.0, 3.0):
            s2.add(x)
        s1.merge(s2)  # into empty: adopt
        assert s1.count == 3 and s1.mean() == 2.0
        s3 = StreamingStat()
        s1.merge(s3)  # empty other: no-op
        assert s1.count == 3 and s1.mean() == 2.0

    def test_merge_capacity_mismatch_raises(self):
        with pytest.raises(ValueError, match="capacities differ"):
            StreamingStat(capacity=8).merge(StreamingStat(capacity=16))

    def test_merge_is_deterministic(self):
        rng = np.random.default_rng(2)
        xs, ys = rng.uniform(0, 1, 300), rng.uniform(1, 2, 300)

        def build():
            s1 = StreamingStat(capacity=128, seed=11)
            s2 = StreamingStat(capacity=128, seed=12)
            for x in xs:
                s1.add(x)
            for y in ys:
                s2.add(y)
            return s1.merge(s2)

        assert build()._res == build()._res


# ---------------------------------------------------------------------------
# solver integration: spans are the single timing source
# ---------------------------------------------------------------------------
class TestSolverSpans:
    def test_solve_dag_phase_spans_match_profile(self):
        from repro.workflow import solve_dag

        with obs.capture() as cap:
            dec = solve_dag(_dag(), steps=6, restarts=1, num_t=64)
        totals = obs_export.phase_totals(cap)
        ladder = {"starts", "presolve", "triage", "refine", "final_score"}
        assert ladder <= set(totals), totals
        # the decision's profile reads the SAME spans
        assert ladder <= set(dec.profile["phase_us"]), dec.profile
        # solve_dag's ops calls run inside jit, so the kernel tier shows
        # up as compile audit events or not at all (warm cache) — never
        # as in-jit spans (the zero-perturbation jit-boundary rule)
        assert obs_export.span_kinds(cap) == {obs_names.SPAN_SOLVER_PHASE}
        obs_export.validate_records(cap)

    def test_kernel_launch_span_attrs(self):
        from repro.kernels import ops

        W = np.full((2, 3), 1 / 3, np.float32)
        mus = np.linspace(10, 20, 6).reshape(2, 3).astype(np.float32)
        sigmas = np.full((2, 3), 1.5, np.float32)
        with obs.capture() as cap:
            ops.frontier_moments(W, mus, sigmas, num_t=32)
        launches = [r for r in cap
                    if r["name"] == obs_names.SPAN_KERNEL_LAUNCH]
        assert launches, cap
        at = launches[0]["attrs"]
        assert at["mode"] == "fwd" and at["F"] == 2 and at["K"] == 3
        assert at["autotune"] in ("hit", "miss", "explicit", "none")


# ---------------------------------------------------------------------------
# zero perturbation: bitwise-identical behavior traced vs untraced
# ---------------------------------------------------------------------------
def _engine_run(ticks=5, seed=0):
    from repro.serve.engine import WorkflowEngine

    templates = {"wf": _dag(k=2, seed=3)}
    eng = WorkflowEngine(templates, max_live=8, lam_var=0.02, num_t=64,
                        seed=seed, prior_obs=2, settle_steps=2)
    rng = np.random.default_rng(seed)
    outs = []
    for _ in range(ticks):
        arrivals = [("wf", 30.0)] * int(rng.poisson(2.0))
        out = eng.tick(arrivals)
        outs.append((out["live"], out["queue"], out["rows"],
                     out["launches"],
                     tuple(round(r["join_latency_s"], 12)
                           for r in out["retired"])))
    return outs


@pytest.mark.fault
class TestZeroPerturbation:
    def test_engine_ticks_bitwise_traced_vs_untraced(self, tracing):
        obs.set_enabled(False)
        plain = _engine_run()
        obs.set_enabled(True)
        obs.clear()
        traced = _engine_run()
        assert plain == traced
        assert obs.records(), "traced run recorded nothing"

    def test_chaos_parity_holds_with_tracing(self, tracing):
        from repro.sim.chaos import run_chaos_trace

        obs.set_enabled(False)
        res_plain = run_chaos_trace(num_channels=4, ticks=6, kill_every=3)
        obs.set_enabled(True)
        obs.clear()
        res = run_chaos_trace(num_channels=4, ticks=6, kill_every=3)
        # parity verified continuously INSIDE the traced run...
        assert res.kills == 1 and res.parity_checks == 1
        # ...and the traced trajectory is bitwise the untraced one
        np.testing.assert_array_equal(res.joins, res_plain.joins)
        recs = obs.records()
        obs_export.validate_records(recs)
        restores = [r for r in recs
                    if r["name"] == obs_names.EV_CKPT_RESTORE]
        assert [(r["attrs"]["step"], r["attrs"]["kind"])
                for r in restores] == [(3, "balancer")]
        assert obs_names.SPAN_CHAOS_CYCLE in obs_export.span_kinds(recs)

    def test_workflow_chaos_restore_event_carries_manifest_step(
            self, tracing):
        from repro.sim.chaos import run_workflow_chaos_trace

        res = run_workflow_chaos_trace(_dag(), ticks=4, kill_every=2)
        assert res.kills == 1 and res.parity_checks == 1
        restores = [r for r in obs.records()
                    if r["name"] == obs_names.EV_CKPT_RESTORE]
        assert [(r["attrs"]["step"], r["attrs"]["kind"])
                for r in restores] == [(2, "workflow")]

    def test_trace_state_not_checkpointed(self, tracing, tmp_path):
        from repro.ckpt import save_pipeline
        from repro.sched import UncertaintyAwareBalancer

        bal = UncertaintyAwareBalancer(num_channels=3, lam=0.05,
                                       explore=0.0)
        rng = np.random.default_rng(0)
        for _ in range(3):
            bal.observe(rng.uniform(8, 30, 3), np.full(3, 1 / 3))
        with obs.span(obs_names.SPAN_SCHED_REFRESH, kind="fleet"):
            bal.weights()
        path = save_pipeline(str(tmp_path), 1, bal)
        with open(f"{path}/meta.json") as f:
            manifest = f.read()
        # no trace/span/obs state rides the manifest — a restored replica
        # starts a FRESH trace whose first record is the restore event
        assert "trace" not in manifest and "span" not in manifest
