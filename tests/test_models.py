"""Per-arch smoke tests (reduced configs) + decode-consistency + causality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _assert_decode_close(actual, desired, atol=5e-4, rtol=5e-3,
                         mismatch_fraction=1e-3, slack=10.0):
    """Tolerance check robust to isolated f32-reordering outliers.

    Decode recurrences and chunked-scan forwards accumulate in different
    orders, so a handful of near-cancelling logits can land just outside a
    strict elementwise tolerance (observed: 1/24576 at 1.2x tol on jamba).
    Rather than loosening the tolerance for every element, keep it strict for
    the bulk, cap ALL elements at ``slack``x the tolerance, and allow at most
    ``mismatch_fraction`` of elements between the two.
    """
    actual = np.asarray(actual, np.float64)
    desired = np.asarray(desired, np.float64)
    err = np.abs(actual - desired)
    tol = atol + rtol * np.abs(desired)
    over = err > tol
    assert np.all(err <= slack * tol), (
        f"decode mismatch beyond {slack}x tolerance: "
        f"max {(err / tol).max():.2f}x at {np.unravel_index(np.argmax(err / tol), err.shape)}")
    frac = over.mean()
    assert frac <= mismatch_fraction, (
        f"{over.sum()}/{over.size} elements ({frac:.4%}) outside tolerance "
        f"(allowed {mismatch_fraction:.4%})")


def _dropless(cfg):
    """Capacity high enough that no token copy is dropped (exactness tests)."""
    if cfg.num_experts:
        return cfg.replace(capacity_factor=float(cfg.num_experts))
    return cfg


def _fwd(model, cfg, params, tokens, frames=None, patches=None):
    if cfg.is_encoder_decoder:
        return model.apply(params, tokens, frames)
    if cfg.num_patches:
        return model.apply(params, tokens, patches)
    return model.apply(params, tokens)


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward(arch):
    """Reduced same-family config: one forward step, shape + finiteness."""
    cfg = get_config(arch).tiny()
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 32
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    frames = patches = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.num_patches:
        patches = jax.random.normal(KEY, (B, cfg.num_patches, cfg.d_model))
        tokens = tokens[:, :S - cfg.num_patches]
    logits = _fwd(model, cfg, params, tokens, frames, patches)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    """One real train step on CPU: loss finite, params move."""
    from repro.optim.adamw import cosine_schedule
    from repro.train.step import init_state, make_train_step

    cfg = get_config(arch).tiny()
    model = build_model(cfg)
    state = init_state(model, KEY)
    step = jax.jit(make_train_step(model, cfg, cosine_schedule(1e-3, 2, 10)))
    B, S = 2, 32
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    labels = tokens
    extra = None
    if cfg.is_encoder_decoder:
        extra = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.num_patches:
        extra = jax.random.normal(KEY, (B, cfg.num_patches, cfg.d_model))
        tokens = tokens[:, :S - cfg.num_patches]
        labels = jnp.concatenate(
            [jnp.full((B, cfg.num_patches), -1, jnp.int32), tokens], axis=1)
    state2, metrics = step(state, tokens, labels, extra)
    assert np.isfinite(float(metrics["loss"]))
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(state2.params)))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-v2-lite-16b",
                                  "mamba2-2.7b", "jamba-1.5-large-398b",
                                  "h2o-danube-1.8b"])
def test_decode_matches_full_forward(arch):
    """Token-by-token decode == teacher-forced forward (dropless MoE)."""
    cfg = _dropless(get_config(arch).tiny())
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 24
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = model.apply(params, tokens)
    cache = model.cache_init(B, S if not cfg.window else min(S, cfg.window))
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, tokens[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    _assert_decode_close(dec, full, atol=5e-4, rtol=5e-3)


def test_prefill_then_decode_continuation():
    cfg = _dropless(get_config("qwen3-8b").tiny())
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (2, 17), 0, cfg.vocab_size)
    _, cache = jax.jit(lambda p, t: model.prefill(p, t, cache_len=32))(
        params, tokens[:, :16])
    lg, _ = jax.jit(model.decode_step)(params, cache, tokens[:, 16:17])
    expect = model.apply(params, tokens)[:, 16]
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(expect),
                               atol=2e-4, rtol=2e-3)


def test_swa_ring_buffer_decode():
    """SWA cache smaller than the sequence still matches full forward."""
    cfg = get_config("h2o-danube-1.8b").tiny().replace(window=8)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 1, 24
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = model.apply(params, tokens)
    cache = model.cache_init(B, cfg.window)  # ring of 8 slots for 24 tokens
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, tokens[:, t:t + 1])
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=5e-4, rtol=5e-3)


def test_causality():
    """Perturbing a future token must not change past logits."""
    cfg = get_config("qwen3-8b").tiny()
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    t2 = tokens.at[0, 10].set((tokens[0, 10] + 1) % cfg.vocab_size)
    l1 = model.apply(params, tokens)
    l2 = model.apply(params, t2)
    np.testing.assert_allclose(np.asarray(l1[:, :10]), np.asarray(l2[:, :10]),
                               atol=1e-5)
    assert float(jnp.max(jnp.abs(l1[:, 10:] - l2[:, 10:]))) > 1e-4


def test_mamba_causality():
    cfg = get_config("mamba2-2.7b").tiny()
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (1, 32), 0, cfg.vocab_size)
    t2 = tokens.at[0, 20].set((tokens[0, 20] + 1) % cfg.vocab_size)
    l1 = model.apply(params, tokens)
    l2 = model.apply(params, t2)
    np.testing.assert_allclose(np.asarray(l1[:, :20]), np.asarray(l2[:, :20]),
                               atol=1e-4)


def test_moe_local_vs_shardmap_identical():
    from repro.launch.mesh import make_local_mesh
    from repro.models.transformer import LM, ShardCtx

    cfg = get_config("qwen3-moe-235b-a22b").tiny()
    mesh = make_local_mesh(("data", "model"))
    lm_local = LM(cfg)
    lm_sm = LM(cfg, ShardCtx(mesh=mesh, batch_axes=("data",)))
    params = lm_local.init(KEY)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(lm_local.apply(params, tokens)),
                               np.asarray(lm_sm.apply(params, tokens)),
                               atol=1e-5)


def test_moe_grads_finite_through_shardmap():
    from repro.launch.mesh import make_local_mesh
    from repro.models.transformer import LM, ShardCtx

    cfg = get_config("deepseek-v2-lite-16b").tiny()
    mesh = make_local_mesh(("data", "model"))
    lm = LM(cfg, ShardCtx(mesh=mesh, batch_axes=("data",)))
    params = lm.init(KEY)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)

    def loss(p):
        return jnp.mean(lm.apply(p, tokens).astype(jnp.float32) ** 2)

    g = jax.jit(jax.grad(loss))(params)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))
    assert sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g)) > 0


def test_decode_pallas_impl_matches_xla():
    """End-to-end decode with the Pallas flash-decode kernel (interpret mode)
    must match the XLA decode path exactly."""
    cfg = get_config("qwen3-8b").tiny()
    model_x = build_model(cfg)
    params = model_x.init(KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    cfg_p = cfg.replace(attention_impl="pallas_interpret")
    model_p = build_model(cfg_p)

    cx = model_x.cache_init(2, 16)
    cp = model_p.cache_init(2, 16)
    for t in range(4):
        lx, cx = model_x.decode_step(params, cx, tokens[:, t:t + 1])
        lp, cp = model_p.decode_step(params, cp, tokens[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                                   atol=2e-4, rtol=2e-3)
