"""Degraded stand-in for ``hypothesis`` when it is not installed.

The real dependency is recorded in requirements-dev.txt; CI images that lack
it must still *collect and run* the property tests. This shim replays each
``@given`` property as a fixed-seed parametrized sweep: every strategy grows a
``sample(rng)`` method and the decorator draws ``max_examples`` (capped) seeded
examples per test. No shrinking, no edge-case database — strictly weaker than
hypothesis, but deterministic and better than losing the tests entirely.
"""
from __future__ import annotations

import numpy as np

_FALLBACK_CAP = 10  # examples per property without real hypothesis


class _Strategy:
    def __init__(self, sampler):
        self._sampler = sampler

    def sample(self, rng):
        return self._sampler(rng)


class _Strategies:
    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.sample(rng) for s in strategies))


st = _Strategies()


def settings(max_examples: int = _FALLBACK_CAP, deadline=None, **_ignored):
    """Records the example budget on the (already-``given``-wrapped) test."""

    def apply(fn):
        fn._max_examples = min(max_examples, _FALLBACK_CAP)
        return fn

    return apply


def given(*strategies):
    """Fixed-seed replacement: run the property on seeded random draws."""

    def decorate(fn):
        # no functools.wraps: pytest must NOT see the original signature,
        # or it would treat the strategy-filled parameters as fixtures
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _FALLBACK_CAP)
            for example in range(n):
                rng = np.random.default_rng(example)
                drawn = tuple(s.sample(rng) for s in strategies)
                fn(*args, *drawn, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper._max_examples = _FALLBACK_CAP
        return wrapper

    return decorate
