#!/usr/bin/env bash
# CI entry point: tier-1 test suite + the reduced-scale benchmark smoke.
#
# Default keeps the run fast by deselecting tests marked `slow`
# (pyproject.toml defines the marker); pass --full to run everything the
# ROADMAP tier-1 command runs (`PYTHONPATH=src python -m pytest -x -q`),
# plus the bench smoke either way. Extra args go to pytest verbatim, e.g.
#   scripts/ci.sh -k families
set -euo pipefail
cd "$(dirname "$0")/.."

MARKER=(-m "not slow")
if [[ "${1:-}" == "--full" ]]; then
    MARKER=()
    shift
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q "${MARKER[@]}" "$@"

scripts/bench_smoke.sh
