#!/usr/bin/env bash
# CI entry point: lint tier + tier-1 test suite + the reduced-scale
# benchmark smoke.
#
# Tiers:
#   (default) --fast : deselect `slow`, `mc_oracle` AND `sanitizer` tests —
#                      the Monte-Carlo ground-truth comparisons burn minutes
#                      of sampling and guard math that the FD/autodiff parity
#                      tests also cover; the checkify-backed sanitizer tests
#                      retrace the solvers. Run them when the quadrature, a
#                      family's sampling, or the sanitizer tier changes.
#   --full           : everything the ROADMAP tier-1 command runs
#                      (`PYTHONPATH=src python -m pytest -x -q`), PLUS a
#                      second tier-1 fast pass under REPRO_SANITIZE=1 so the
#                      runtime invariant checks ride every frontier path
#                      before the benchmarks run.
# Extra args go to pytest verbatim, e.g.  scripts/ci.sh -k families
#
# The lint tier always runs first: scripts/lint.py (the repo's own AST
# rules — see docs/INVARIANTS.md) must exit clean, and ruff (config in
# pyproject.toml) runs when installed — the container image doesn't ship
# it, so its absence is not a failure.
#
# After the tests, the bench smoke runs, then the trace tier (serve smoke
# under REPRO_TRACE=1: JSONL/Perfetto export validity + the <5% overhead
# contract), and every repo-root BENCH_*.json is checked: it must parse and
# carry the schema keys its benchmark promises — trajectory readers break
# silently otherwise.
set -euo pipefail
cd "$(dirname "$0")/.."

MARKER=(-m "not slow and not mc_oracle and not sanitizer")
SANITIZE_PASS=0
case "${1:-}" in
    --full) MARKER=(); SANITIZE_PASS=1; shift ;;
    --fast) shift ;;
esac

echo "== lint tier =="
python scripts/lint.py
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks scripts
else
    echo "ruff not installed; skipping (scripts/lint.py is the gate)"
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q "${MARKER[@]}" "$@"

if [ "$SANITIZE_PASS" = 1 ]; then
    echo "== sanitizer tier: tier-1 fast under REPRO_SANITIZE=1 =="
    REPRO_SANITIZE=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m pytest -x -q -m "not slow and not mc_oracle" "$@"
fi

# Chaos smoke: the fault-tolerance tier (kill/restore tick parity, churn
# traces, checkpoint manifests) runs under the sanitizer so probability-
# domain and finiteness checks ride every fault path too. The `fault`
# marker selects it; it is small enough to run on every tier.
echo "== chaos tier: fault-marked tests under REPRO_SANITIZE=1 =="
REPRO_SANITIZE=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q -m "fault and not slow and not mc_oracle"

scripts/bench_smoke.sh

# Trace tier: the cross-layer observability contract (docs/OBSERVABILITY.md).
# The serve-engine smoke re-runs under REPRO_TRACE=1: tracing must not
# perturb the run (the overwritten BENCH_serve_trace_smoke.json re-passes
# the schema tier below with the same engine numbers, plus a `trace`
# section), the exported JSONL must validate against the event schema with
# real cross-layer coverage (>= 4 span kinds, >= 3 audit event types), the
# Perfetto export must be loadable, and the traced-vs-untraced solver
# wall-clock overhead must stay under 5%.
echo "== trace tier: serve_trace smoke under REPRO_TRACE=1 =="
REPRO_TRACE=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.serve_trace --smoke --json
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'PY'
import json

from repro.obs import export as obs_export

recs = obs_export.read_jsonl("TRACE_serve_trace_smoke.jsonl")
n = obs_export.validate_records(recs)
kinds = obs_export.span_kinds(recs)
types = obs_export.event_types(recs)
assert len(kinds) >= 4, f"span kinds not cross-layer: {sorted(kinds)}"
assert len(types) >= 3, f"audit event types too few: {sorted(types)}"
with open("TRACE_serve_trace_smoke.perfetto.json") as f:
    pf = json.load(f)
assert pf["traceEvents"], "perfetto export is empty"
with open("BENCH_serve_trace_smoke.json") as f:
    d = json.load(f)
tr = d["trace"]
assert tr["overhead_pct"] < 5.0, \
    f"tracing overhead {tr['overhead_pct']}% breaks the <5% contract"
assert tr["dropped"] == 0, f"trace ring dropped records: {tr}"
print(f"trace tier OK: {n} records, {len(kinds)} span kinds, "
      f"{len(types)} audit event types, overhead {tr['overhead_pct']}%")
PY

python - <<'PY'
import glob
import json

# single source: the schema each benchmark promises is declared next to its
# writer and imported here — no hand-copied key lists to drift
from benchmarks import cluster_scale, dag_scale, fault_trace, serve_trace

SCHEMAS = {
    "cluster_scale": cluster_scale.SCHEMA_KEYS,
    "serve_trace": serve_trace.SCHEMA_KEYS,
    "dag_scale": dag_scale.SCHEMA_KEYS,
    "fault_trace": fault_trace.SCHEMA_KEYS,
}
ENTRY_KEYS = {
    "cluster_scale": cluster_scale.ENTRY_KEYS,
    "serve_trace": serve_trace.ENTRY_KEYS,
    "dag_scale": dag_scale.ENTRY_KEYS,
    "fault_trace": fault_trace.ENTRY_KEYS,
}

paths = sorted(glob.glob("BENCH_*.json"))
assert paths, "no BENCH_*.json found at the repo root"
for path in paths:
    with open(path) as f:
        d = json.load(f)   # must parse
    bench = d.get("bench")
    assert bench in SCHEMAS, f"{path}: unknown bench tag {bench!r}"
    missing = [k for k in SCHEMAS[bench] if k not in d]
    assert not missing, f"{path}: missing schema keys {missing}"
    for e in d["entries"]:
        gone = [k for k in ENTRY_KEYS[bench] if k not in e]
        assert not gone, f"{path}: entry {e.get('name')} missing {gone}"
    print(f"{path}: schema OK ({bench}, {len(d['entries'])} entries)")

# dag_scale carries the PR 8 multi-fidelity acceptance surface beyond the
# generic schema: the joint solve's wall time must be attributed across the
# ladder phases, the joint/greedy wall-clock ratio must be present (and <= 1
# at the tracked full scale), and a 512-stage scale point must exist — at
# full scale as a 512 x K=256 entry
for path in sorted(glob.glob("BENCH_dag_scale*.json")):
    with open(path) as f:
        d = json.load(f)
    phases = set(dag_scale.PHASE_KEYS)
    assert phases <= set(d["joint_phase_us"]), (
        f"{path}: joint_phase_us missing "
        f"{phases - set(d['joint_phase_us'])}")
    sp = d["scale_point"]
    assert sp["stages"] == 512, f"{path}: scale point at {sp['stages']} stages"
    assert phases <= set(sp["phase_us"]), (
        f"{path}: scale-point phase_us missing {phases - set(sp['phase_us'])}")
    names = {e["name"] for e in d["entries"]}
    assert "joint_solve_xla_scale" in names, f"{path}: no scale entry: {names}"
    ratio = d["joint_vs_greedy_wallclock_ratio"]
    assert ratio > 0, f"{path}: bad wall-clock ratio {ratio}"
    if not d["smoke"]:
        assert any(e["S"] == 512 and e["K"] == 256 for e in d["entries"]), \
            f"{path}: full-scale file lacks the 512-stage x K=256 entry"
        assert ratio <= 1.0, \
            f"{path}: joint slower than greedy at full scale ({ratio})"
    print(f"{path}: dag_scale acceptance OK (ratio {ratio}, "
          f"scale point {sp['stages']}st x K={sp['channels']})")

# serve_trace carries the PR 9 continuous-batching acceptance surface: the
# streaming-telemetry percentiles must be populated (p99 join latency,
# solver-tick wall-clock, rows-per-launch occupancy), batching must beat the
# per-instance-loop baseline on the engine's own row sets, and the tracked
# full-scale file must show >=256 concurrent live instances with a >=4x
# batched-vs-looped margin
for path in sorted(glob.glob("BENCH_serve_trace*.json")):
    with open(path) as f:
        d = json.load(f)
    lat = d["latency"]
    assert lat["count"] > 0 and lat["p99"] >= lat["p50"] > 0, f"{path}: {lat}"
    st = d["solver_tick_us"]
    assert st["count"] > 0 and st["p99"] >= st["p50"] > 0, f"{path}: {st}"
    rpl = d["rows_per_launch"]
    assert rpl["count"] > 0 and rpl["max"] >= 1, f"{path}: {rpl}"
    ratio = d["batched_vs_looped_ratio"]
    assert ratio > 1.0, f"{path}: batching no faster than the loop ({ratio})"
    fams = {t["family"] for t in d["templates"].values()}
    assert len(fams) >= 3, f"{path}: template families not diverse: {fams}"
    if not d["smoke"]:
        assert d["live_instances"]["max"] >= 256, \
            f"{path}: full scale never held 256 live instances " \
            f"({d['live_instances']['max']})"
        assert ratio >= 4.0, \
            f"{path}: batched solve under 4x vs per-instance loop ({ratio})"
    print(f"{path}: serve_trace acceptance OK (ratio {ratio}x, "
          f"live max {d['live_instances']['max']}, "
          f"p99 join {lat['p99']:.3f}s)")
PY
