#!/usr/bin/env bash
# CI entry point: tier-1 test suite + the reduced-scale benchmark smoke.
#
# Tiers:
#   (default) --fast : deselect `slow` AND `mc_oracle` tests — the
#                      Monte-Carlo ground-truth comparisons burn minutes of
#                      sampling and guard math that the FD/autodiff parity
#                      tests also cover; run them when the quadrature or a
#                      family's sampling changes.
#   --full           : everything the ROADMAP tier-1 command runs
#                      (`PYTHONPATH=src python -m pytest -x -q`).
# Extra args go to pytest verbatim, e.g.  scripts/ci.sh -k families
#
# After the tests, the bench smoke runs, and every repo-root BENCH_*.json is
# checked: it must parse and carry the schema keys its benchmark promises —
# trajectory readers break silently otherwise.
set -euo pipefail
cd "$(dirname "$0")/.."

MARKER=(-m "not slow and not mc_oracle")
case "${1:-}" in
    --full) MARKER=(); shift ;;
    --fast) shift ;;
esac

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q "${MARKER[@]}" "$@"

scripts/bench_smoke.sh

python - <<'PY'
import glob
import json

# single source: the schema each benchmark promises is declared next to its
# writer and imported here — no hand-copied key lists to drift
from benchmarks import cluster_scale, dag_scale, serve_trace

SCHEMAS = {
    "cluster_scale": cluster_scale.SCHEMA_KEYS,
    "serve_trace": serve_trace.SCHEMA_KEYS,
    "dag_scale": dag_scale.SCHEMA_KEYS,
}
ENTRY_KEYS = {
    "cluster_scale": cluster_scale.ENTRY_KEYS,
    "serve_trace": serve_trace.ENTRY_KEYS,
    "dag_scale": dag_scale.ENTRY_KEYS,
}

paths = sorted(glob.glob("BENCH_*.json"))
assert paths, "no BENCH_*.json found at the repo root"
for path in paths:
    with open(path) as f:
        d = json.load(f)   # must parse
    bench = d.get("bench")
    assert bench in SCHEMAS, f"{path}: unknown bench tag {bench!r}"
    missing = [k for k in SCHEMAS[bench] if k not in d]
    assert not missing, f"{path}: missing schema keys {missing}"
    for e in d["entries"]:
        gone = [k for k in ENTRY_KEYS[bench] if k not in e]
        assert not gone, f"{path}: entry {e.get('name')} missing {gone}"
    print(f"{path}: schema OK ({bench}, {len(d['entries'])} entries)")
PY
