#!/usr/bin/env bash
# Reduced-scale cluster_scale benchmark smoke: exercises the forward tick, the
# fused analytic-VJP PGD tick (both impls, gradient-parity asserted), and the
# autotune sweep/cache end-to-end in well under a minute, then sanity-checks
# the machine-readable output. The full-scale run
# (`python -m benchmarks.cluster_scale --json`) maintains the repo-root
# BENCH_cluster_scale.json perf trajectory; this writes the _smoke variant so
# it never clobbers tracked full-scale numbers.
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.cluster_scale --json --smoke --ticks-only

python - <<'PY'
import json

d = json.load(open("BENCH_cluster_scale_smoke.json"))
names = {e["name"] for e in d["entries"]}
assert any(n.startswith("pgd_tick_autodiff") for n in names), names
assert any(n.startswith("pgd_tick_fused_xla") for n in names), names
fams = {e.get("family") for e in d["entries"]}
assert {"normal", "lognormal", "drift"} <= fams, fams  # family tick section ran
assert any(n.startswith("lognormal_tick_fused") for n in names), names
assert all(e["median_us"] > 0 for e in d["entries"])
print(f"bench smoke OK: {len(d['entries'])} entries "
      f"(families: {sorted(f for f in fams if f)}), "
      f"fused/autodiff speedup {d['pgd_speedup_vs_autodiff']}x (smoke scale)")
PY
