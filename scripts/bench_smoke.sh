#!/usr/bin/env bash
# Reduced-scale cluster_scale benchmark smoke: exercises the forward tick, the
# fused analytic-VJP PGD tick (both impls, gradient-parity asserted), and the
# autotune sweep/cache end-to-end in well under a minute, then sanity-checks
# the machine-readable output. The full-scale run
# (`python -m benchmarks.cluster_scale --json`) maintains the repo-root
# BENCH_cluster_scale.json perf trajectory; this writes the _smoke variant so
# it never clobbers tracked full-scale numbers.
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.cluster_scale --json --smoke --ticks-only

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.serve_trace --json --smoke

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.dag_scale --json --smoke

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.fault_trace --json --smoke

python - <<'PY'
import json

d = json.load(open("BENCH_cluster_scale_smoke.json"))
names = {e["name"] for e in d["entries"]}
assert any(n.startswith("pgd_tick_autodiff") for n in names), names
assert any(n.startswith("pgd_tick_fused_xla") for n in names), names
fams = {e.get("family") for e in d["entries"]}
assert {"normal", "lognormal", "drift", "auto"} <= fams, fams  # all sections
assert any(n.startswith("lognormal_tick_fused") for n in names), names
assert any(n.startswith("auto_tick_score_plus_fused") for n in names), names
assert all(e["median_us"] > 0 for e in d["entries"])
print(f"bench smoke OK: {len(d['entries'])} entries "
      f"(families: {sorted(f for f in fams if f)}), "
      f"fused/autodiff speedup {d['pgd_speedup_vs_autodiff']}x, "
      f"auto-family overhead {d['auto_family_tick_overhead']}x (smoke scale)")

s = json.load(open("BENCH_serve_trace_smoke.json"))
assert s["bench"] == "serve_trace" and s["ticks"] > 0
assert {"mean", "var", "p50", "p99"} <= set(s["latency"]), s["latency"]
# the continuous-batching engine's acceptance surface, smoke edition: the
# solver tick and its occupancy telemetry must be present, at least three
# completion-time families must have ridden stacked launches, and batching
# must already beat the per-instance loop (the >=4x margin is a full-scale
# gate in scripts/ci.sh)
assert s["solver_tick_us"]["count"] > 0, s["solver_tick_us"]
assert s["rows_per_launch"]["count"] > 0, s["rows_per_launch"]
fams = {t["family"] for t in s["templates"].values()}
assert len(fams) >= 3, f"template families not diverse: {fams}"
assert s["batched_vs_looped_ratio"] > 1.0, s["batched_vs_looped_ratio"]
assert {"calm", "burst"} <= set(s["regimes"]), s["regimes"]
assert s["slo"]["retired"] > 0, s["slo"]
print(f"serve trace smoke OK: {s['ticks']} ticks, "
      f"families {sorted(fams)}, "
      f"batched vs looped {s['batched_vs_looped_ratio']}x, "
      f"latency mean {s['latency']['mean']:.3f}s p99 {s['latency']['p99']:.3f}s")

g = json.load(open("BENCH_dag_scale_smoke.json"))
assert g["bench"] == "dag_scale" and g["stages"] > 0
# the joint solve must route every stage's moments through ONE stacked
# launch per family (the workflow subsystem's acceptance contract) even at
# smoke scale; the improvement margin is only asserted at full scale
assert g["single_batched_path"], g["family_groups"]
names = {e["name"] for e in g["entries"]}
assert {"joint_solve_xla", "greedy_solve_xla"} <= names, names
# the fidelity ladder must attribute the joint wall time across its phases,
# and the 512-stage scale point must ride even at smoke scale (structure
# intact, K/quadrature/steps shrunk) so the scaled composition path is
# exercised on every CI run
assert {"starts", "presolve", "triage", "refine",
        "final_score"} <= set(g["joint_phase_us"]), g["joint_phase_us"]
assert g["joint_vs_greedy_wallclock_ratio"] > 0
assert g["scale_point"]["stages"] == 512, g["scale_point"]
assert "joint_solve_xla_scale" in names, names
print(f"dag scale smoke OK: {g['stages']} stages x K={g['channels']}, "
      f"family groups {g['family_groups']}, "
      f"joint vs greedy {g['improvement_pct']}% "
      f"(realized {g['realized_improvement_pct']}%, "
      f"wallclock ratio {g['joint_vs_greedy_wallclock_ratio']}), "
      f"scale point {g['scale_point']['stages']}st")

ft = json.load(open("BENCH_fault_trace_smoke.json"))
assert ft["bench"] == "fault_trace" and ft["ticks"] > 0
assert ft["mean_fail_p"] >= 0.05, ft["mean_fail_p"]   # >=5% attempt churn
assert {"blind", "aware"} <= set(ft["makespan"]), ft["makespan"]
# the acceptance contract: under real attempt churn, pricing the failure
# physics (Defective) must realize a strictly better makespan than the
# failure-blind normal-family solve on the identical trace
assert ft["improvement_pct"] > 0, \
    f"failure-aware solver did not beat the blind one: {ft['improvement_pct']}%"
print(f"fault trace smoke OK: {ft['ticks']} ticks, "
      f"mean fail_p {ft['mean_fail_p']:.3f}, "
      f"aware beats blind by {ft['improvement_pct']:.2f}%")
PY
