#!/usr/bin/env python
"""Trace viewer prep: JSONL trace -> Chrome/Perfetto + per-kind summaries.

Usage (from the repo root)::

    python scripts/trace_view.py TRACE_serve_trace_smoke.jsonl
    python scripts/trace_view.py trace.jsonl -o trace.perfetto.json
    python scripts/trace_view.py trace.jsonl --prometheus

Reads a ``repro.obs`` JSONL trace (one record per line, as written by
``repro.obs.export.write_jsonl`` / the serve CLI's ``--trace``), validates
every record against the event schema, writes the Chrome trace_event file
Perfetto and chrome://tracing load directly, and prints a per-name summary
table (count, total/mean duration for spans; count per audit event type).
``--prometheus`` additionally prints the text-format metrics snapshot.
"""
import argparse
import os
import sys
from collections import defaultdict

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.obs import export as obs_export  # noqa: E402


def summarize(records) -> str:
    """Per-name table: spans get count/total/mean µs, events get counts."""
    spans = defaultdict(list)
    events = defaultdict(int)
    for r in records:
        if r["type"] == "span":
            spans[r["name"]].append(float(r["dur_us"]))
        else:
            events[r["name"]] += 1
    lines = [f"{'name':<24}{'count':>8}{'total_us':>14}{'mean_us':>12}"]
    for name in sorted(spans):
        ds = spans[name]
        lines.append(f"{name:<24}{len(ds):>8}{sum(ds):>14.1f}"
                     f"{sum(ds) / len(ds):>12.1f}")
    for name in sorted(events):
        lines.append(f"{name:<24}{events[name]:>8}{'-':>14}{'-':>12}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("jsonl", help="JSONL trace file (repro.obs records)")
    ap.add_argument("-o", "--out", default=None,
                    help="Perfetto output path (default: "
                         "<input stem>.perfetto.json)")
    ap.add_argument("--prometheus", action="store_true",
                    help="also print the Prometheus text-format snapshot")
    args = ap.parse_args(argv)

    records = obs_export.read_jsonl(args.jsonl)
    n = obs_export.validate_records(records)
    out = args.out or (os.path.splitext(args.jsonl)[0] + ".perfetto.json")
    obs_export.write_perfetto(records, out)

    kinds = obs_export.span_kinds(records)
    types = obs_export.event_types(records)
    print(f"{args.jsonl}: {n} records, {len(kinds)} span kinds, "
          f"{len(types)} audit event types -> {out}")
    print(summarize(records))
    if args.prometheus:
        print(obs_export.prometheus_snapshot(records))
    return 0


if __name__ == "__main__":
    sys.exit(main())
