#!/usr/bin/env python
"""Repo lint entry point: runs the repro.analysis invariant linter.

Thin wrapper so CI and developers can say ``python scripts/lint.py`` from the
repo root without setting PYTHONPATH; all behavior (flags, exit codes) is
``python -m repro.analysis`` — see docs/INVARIANTS.md for the rule catalogue.
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.analysis.__main__ import main  # noqa: E402

if __name__ == "__main__":
    os.chdir(_ROOT)
    argv = sys.argv[1:] or ["src", "tests", "benchmarks", "scripts"]
    sys.exit(main(argv))
