"""Generate the §Dry-run and §Roofline markdown tables from dry-run JSONs."""
import glob
import json
import os
import sys

DRY = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"

recs = []
for p in sorted(glob.glob(os.path.join(DRY, "*.json"))):
    with open(p) as f:
        recs.append(json.load(f))

order_shape = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3,
               "train_4k(partitioned)": 4}
recs.sort(key=lambda r: (r["arch"], order_shape.get(r["shape"], 9), r["mesh"]))


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


print("### Dry-run table (per-device memory_analysis, compile status)\n")
print("| arch | shape | mesh | status | args GiB/dev | temp GiB/dev | "
      "compile s | collectives (count by type) |")
print("|---|---|---|---|---|---|---|---|")
for r in recs:
    if r["status"] == "skipped":
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | - | - | - | "
              f"{r['reason'][:60]} |")
        continue
    if r["status"] != "ok":
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAIL** | - | - | - | "
              f"{r.get('error','')[:60]} |")
        continue
    m = r["memory_analysis"]
    cc = r["hlo_stats"]["collective_counts"]
    abbrev = {"all-gather": "ag", "all-reduce": "ar", "reduce-scatter": "rs",
              "all-to-all": "a2a", "collective-permute": "cp"}
    cstr = ", ".join(f"{abbrev.get(k, k)}:{v}" for k, v in sorted(cc.items()))
    print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
          f"{fmt_bytes(m['argument_bytes'])} | {fmt_bytes(m['temp_bytes'])} | "
          f"{r['compile_s']} | {cstr} |")

print("\n\n### Roofline table (seconds per step per chip; dominant term bold)\n")
print("| arch | shape | mesh | compute_s | memory_s | collective_s (ici/dcn) | "
      "dominant | bound ms | roofline frac | useful/HLO flops |")
print("|---|---|---|---|---|---|---|---|---|---|")
for r in recs:
    if r["status"] != "ok":
        continue
    t = r["roofline"]
    print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
          f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
          f"| {t['collective_s']:.4f} ({t['ici_s']:.3f}/{t['dcn_s']:.3f}) "
          f"| {t['dominant'].replace('_s','')} "
          f"| {t['step_lower_bound_s']*1e3:.1f} "
          f"| {t['roofline_fraction']:.4f} "
          f"| {(r.get('useful_flops_ratio') or 0):.3f} |")

n_ok = sum(r["status"] == "ok" for r in recs)
n_skip = sum(r["status"] == "skipped" for r in recs)
n_fail = len(recs) - n_ok - n_skip
print(f"\n\ncells: {n_ok} ok, {n_skip} skipped (per assignment rules), {n_fail} failed")


# §Workflow-DAG table: joint vs stage-by-stage greedy from BENCH_dag_scale.json
dag_path = "BENCH_dag_scale.json"
if os.path.exists(dag_path):
    with open(dag_path) as f:
        d = json.load(f)
    print("\n\n### Workflow-DAG partitioning "
          f"({d['stages']} stages x K={d['channels']}; joint solve vs "
          "stage-by-stage greedy)\n")
    print("| method | E[makespan] | Var[makespan] | realized E[makespan] "
          "(paired MC) | solve ms |")
    print("|---|---|---|---|---|")
    times = {e["name"]: e["median_us"] / 1e3 for e in d["entries"]}
    for name, key in (("greedy (per-stage)", "greedy"), ("joint", "joint")):
        m = d[key]
        t = times.get(f"{key}_solve_xla")
        tstr = f"{t:.0f}" if t is not None else "-"
        print(f"| {name} | {m['makespan_mu']:.4f} | {m['makespan_var']:.6f} "
              f"| {m['mc_makespan_mu']:.4f} | {tstr} |")
    print(f"\njoint improvement: {d['improvement_pct']:.3f}% expected "
          f"(realized {d['realized_improvement_pct']:.3f}%), "
          f"{d['family_groups']} stacked kernel launch(es) per moment "
          "evaluation")
